//! Checkpoint / serialization subsystem: fault-tolerant persistence for
//! training runs and trained models.
//!
//! Multi-day pre-training jobs on shared clusters are only viable when a
//! killed run can restart from its last epoch boundary and land on the
//! *exact same* trajectory (paper Section 5; the HydraGNN case study
//! likewise trains from persisted artifacts). This module is the storage
//! half of that story; `coordinator::trainer` wires it into the three
//! training modes and proves bit-identical resume in
//! `rust/tests/integration_checkpoint.rs`.
//!
//! ## Container format
//!
//! One file, little-endian throughout, CRC32-guarded (same `util::crc32`
//! the GPack footer index uses — no new dependencies):
//!
//! ```text
//! "HMCK" | u32 version | u8 kind | u64 payload_len
//! payload bytes (kind-specific, see below)
//! u32 crc32(payload) | "KCMH"
//! ```
//!
//! `kind` 1 is a full training checkpoint ([`TrainCheckpoint`]: model +
//! optimizer moments + metrics log + epoch/stopper cursor + traffic
//! baselines); `kind` 2 is a model-only file ([`save_model`] /
//! [`load_model`]) for inference and warm-start fine-tuning. Any bit flip
//! in the payload is rejected at load time via the CRC; header/trailer
//! damage is rejected via the magics and the length field. Writes go
//! through a temp file + rename so an interrupted save can never leave a
//! torn file under the final name.
//!
//! ## What makes resume bit-identical
//!
//! Every value that feeds the training trajectory is either persisted here
//! or a pure function of `(config, epoch)`:
//!
//! * parameters (encoder + every head) — persisted exactly (f32 bit
//!   patterns, not decimal round-trips),
//! * AdamW first/second moments and step counts — persisted,
//! * the early-stopper cursor (best val loss, consecutive bad epochs) —
//!   persisted,
//! * epoch shuffles — *derived*: the trainer seeds each epoch's RNG as
//!   `seed.wrapping_add(epoch * 7_777_777) ^ tag`, so the "RNG cursor" is
//!   just `epochs_done`,
//! * collectives — rank-order deterministic (see `comm::collectives`).
//!
//! Heads are keyed by **task name**, not registry index: custom-task
//! indices depend on registration order, so a reader must register the
//! same custom tasks the writer used (the same caveat GPack documents) and
//! gets a clear error naming the missing task otherwise.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::coordinator::metrics::{Coverage, EpochMetrics, RunLog};
use crate::coordinator::trainer::{Heads, TrainedModel};
use crate::data::structures::DatasetId;
use crate::model::optimizer::AdamWState;
use crate::model::params::{Init, LeafMeta, ParamSet};
use crate::tensor::{DType, Tensor};
use crate::util::crc32;

const MAGIC: &[u8; 4] = b"HMCK";
const MAGIC_END: &[u8; 4] = b"KCMH";
const VERSION: u32 = 1;
/// Header: magic 4 + version 4 + kind 1 + payload_len 8.
const HEADER_LEN: usize = 17;
/// Trailer: crc 4 + end magic 4.
const TRAILER_LEN: usize = 8;

const KIND_TRAIN: u8 = 1;
const KIND_MODEL: u8 = 2;

// ---------------------------------------------------------------------------
// checkpoint types
// ---------------------------------------------------------------------------

/// Branch-side optimizer state, mirroring [`Heads`]: one shared-branch
/// optimizer, or one per task (keyed by task name — see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum OptHeads {
    Shared(AdamWState),
    PerDataset(Vec<(String, AdamWState)>),
}

/// Everything needed to restart a training run at an epoch boundary and
/// land on the exact same trajectory as an uninterrupted run.
#[derive(Clone)]
pub struct TrainCheckpoint {
    /// `TrainMode::name()` of the run that wrote the file.
    pub mode: String,
    /// `cfg.train.seed` of the run (epoch shuffles derive from it).
    pub train_seed: u64,
    /// `RunConfig::trajectory_fingerprint()` of the run that wrote the
    /// file — resume refuses a config whose trajectory-determining knobs
    /// (replicas, lr, data sizes, ...) differ, not just mode/seed.
    pub config_fingerprint: String,
    /// Epochs fully completed; resume starts at this epoch index.
    pub epochs_done: usize,
    /// Whether early stopping had already fired when this was written.
    pub stopped: bool,
    /// Early-stopper cursor: best val loss seen, consecutive bad epochs.
    pub stopper_best: f64,
    pub stopper_bad_epochs: usize,
    /// Model parameters at the epoch boundary.
    pub model: TrainedModel,
    /// AdamW moments + step count for the shared encoder.
    pub opt_encoder: AdamWState,
    /// AdamW moments + step counts for the branch side.
    pub opt_heads: OptHeads,
    /// Rank-0 metrics log covering epochs `0..epochs_done`.
    pub log: RunLog,
    /// Collective-traffic baselines at save time (global, head-group), so a
    /// resumed run reports cumulative totals.
    pub comm_global: u64,
    pub comm_head: u64,
}

impl TrainCheckpoint {
    /// Pre-flight compatibility check before resuming: same mode, same
    /// training seed (a different seed would produce a different
    /// trajectory — refusing beats silently diverging), a head for every
    /// dataset the run trains on, and an internally consistent file.
    pub fn validate_for(
        &self,
        mode_name: &str,
        train_seed: u64,
        fingerprint: &str,
        datasets: &[DatasetId],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.mode == mode_name,
            "checkpoint was written by mode '{}' but this run is '{}'",
            self.mode,
            mode_name
        );
        anyhow::ensure!(
            self.train_seed == train_seed,
            "checkpoint training seed {} != configured seed {train_seed}; \
             resuming would silently change the trajectory",
            self.train_seed
        );
        anyhow::ensure!(
            self.config_fingerprint == fingerprint,
            "checkpoint was written under a different trajectory config; \
             resuming would silently change the trajectory.\n  saved:      {}\n  \
             configured: {fingerprint}",
            self.config_fingerprint
        );
        anyhow::ensure!(
            self.epochs_done == self.log.epochs.len(),
            "checkpoint is inconsistent: {} epochs done but {} logged",
            self.epochs_done,
            self.log.epochs.len()
        );
        match (&self.model.heads, &self.opt_heads) {
            (Heads::Shared(_), OptHeads::Shared(_)) => {}
            (Heads::PerDataset(heads), OptHeads::PerDataset(opts)) => {
                anyhow::ensure!(
                    heads.len() == opts.len(),
                    "checkpoint has {} heads but {} head optimizer states",
                    heads.len(),
                    opts.len()
                );
                for d in datasets {
                    anyhow::ensure!(
                        heads.contains_key(d),
                        "checkpoint has no head for task {} (trained tasks: {})",
                        d.name(),
                        heads.keys().map(|k| k.name()).collect::<Vec<_>>().join(", ")
                    );
                    anyhow::ensure!(
                        opts.iter().any(|(n, _)| *n == d.name()),
                        "checkpoint has no head optimizer state for task {}",
                        d.name()
                    );
                }
            }
            _ => anyhow::bail!(
                "checkpoint heads/optimizer structure mismatch (shared vs per-dataset)"
            ),
        }
        Ok(())
    }

    /// Branch optimizer state for `d` (PerDataset lookup by task name).
    pub fn opt_for(&self, d: DatasetId) -> anyhow::Result<&AdamWState> {
        match &self.opt_heads {
            OptHeads::Shared(s) => Ok(s),
            OptHeads::PerDataset(v) => {
                let name = d.name();
                v.iter()
                    .find(|(n, _)| *n == name)
                    .map(|(_, s)| s)
                    .ok_or_else(|| {
                        anyhow::anyhow!("no head optimizer state for task {name}")
                    })
            }
        }
    }
}

// ---------------------------------------------------------------------------
// public API
// ---------------------------------------------------------------------------

/// Write a full training checkpoint (atomically: temp file + rename).
pub fn save_train(ckpt: &TrainCheckpoint, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let mut e = Enc::default();
    e.str(&ckpt.mode);
    e.u64(ckpt.train_seed);
    e.str(&ckpt.config_fingerprint);
    e.u64(ckpt.epochs_done as u64);
    e.u8(ckpt.stopped as u8);
    e.f64(ckpt.stopper_best);
    e.u64(ckpt.stopper_bad_epochs as u64);
    enc_model(&mut e, &ckpt.model);
    enc_opt(&mut e, &ckpt.opt_encoder);
    match &ckpt.opt_heads {
        OptHeads::Shared(s) => {
            e.u8(0);
            enc_opt(&mut e, s);
        }
        OptHeads::PerDataset(v) => {
            e.u8(1);
            e.u64(v.len() as u64);
            for (name, s) in v {
                e.str(name);
                enc_opt(&mut e, s);
            }
        }
    }
    enc_log(&mut e, &ckpt.log);
    e.u64(ckpt.comm_global);
    e.u64(ckpt.comm_head);
    write_container(KIND_TRAIN, &e.buf, path.as_ref())
}

/// Load a full training checkpoint, verifying magic, version, and CRC.
pub fn load_train(path: impl AsRef<Path>) -> anyhow::Result<TrainCheckpoint> {
    let payload = read_container(KIND_TRAIN, path.as_ref())?;
    let mut d = Dec { buf: &payload, pos: 0 };
    let mode = d.str()?;
    let train_seed = d.u64()?;
    let config_fingerprint = d.str()?;
    let epochs_done = d.usize()?;
    let stopped = d.u8()? != 0;
    let stopper_best = d.f64()?;
    let stopper_bad_epochs = d.usize()?;
    let model = dec_model(&mut d)?;
    let opt_encoder = dec_opt(&mut d)?;
    let opt_heads = match d.u8()? {
        0 => OptHeads::Shared(dec_opt(&mut d)?),
        1 => {
            let n = d.usize()?;
            anyhow::ensure!(n <= 100_000, "implausible head optimizer count {n}");
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let name = d.str()?;
                v.push((name, dec_opt(&mut d)?));
            }
            OptHeads::PerDataset(v)
        }
        other => anyhow::bail!("unknown opt-heads tag {other}"),
    };
    let log = dec_log(&mut d)?;
    let comm_global = d.u64()?;
    let comm_head = d.u64()?;
    d.finish()?;
    Ok(TrainCheckpoint {
        mode,
        train_seed,
        config_fingerprint,
        epochs_done,
        stopped,
        stopper_best,
        stopper_bad_epochs,
        model,
        opt_encoder,
        opt_heads,
        log,
        comm_global,
        comm_head,
    })
}

/// Write a trained model alone (inference / warm-start artifact).
pub fn save_model(model: &TrainedModel, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let mut e = Enc::default();
    enc_model(&mut e, model);
    write_container(KIND_MODEL, &e.buf, path.as_ref())
}

/// Load a model saved with [`save_model`].
pub fn load_model(path: impl AsRef<Path>) -> anyhow::Result<TrainedModel> {
    let payload = read_container(KIND_MODEL, path.as_ref())?;
    let mut d = Dec { buf: &payload, pos: 0 };
    let model = dec_model(&mut d)?;
    d.finish()?;
    Ok(model)
}

/// Canonical per-epoch checkpoint path: `dir/epoch_0007.ckpt` after 7
/// completed epochs.
pub fn epoch_path(dir: impl AsRef<Path>, epochs_done: usize) -> PathBuf {
    dir.as_ref().join(format!("epoch_{epochs_done:04}.ckpt"))
}

/// Highest-epoch `epoch_*.ckpt` file in `dir`, if any.
pub fn latest_in_dir(dir: impl AsRef<Path>) -> anyhow::Result<Option<PathBuf>> {
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in std::fs::read_dir(dir.as_ref())? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let parsed = name
            .strip_prefix("epoch_")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<usize>().ok());
        if let Some(n) = parsed {
            let better = match &best {
                None => true,
                Some((b, _)) => n > *b,
            };
            if better {
                best = Some((n, entry.path()));
            }
        }
    }
    Ok(best.map(|(_, p)| p))
}

/// Highest-epoch `epoch_*.ckpt` in `dir` that passes full CRC validation
/// (loads as a training checkpoint). Corrupt or truncated files — a crash
/// mid-write, a flipped bit on disk — are warned about and skipped, and
/// the scan falls back to the next-highest epoch. `Ok(None)` when no valid
/// checkpoint survives. This is the rescan `Trainer::train_with_recovery`
/// and `--resume latest` share.
pub fn latest_valid_in_dir(dir: impl AsRef<Path>) -> anyhow::Result<Option<PathBuf>> {
    let dir = dir.as_ref();
    let mut found: Vec<(usize, PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let parsed = name
            .strip_prefix("epoch_")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<usize>().ok());
        if let Some(n) = parsed {
            found.push((n, entry.path()));
        }
    }
    found.sort_by(|a, b| b.0.cmp(&a.0));
    for (epoch, path) in found {
        match load_train(&path) {
            Ok(_) => return Ok(Some(path)),
            Err(e) => eprintln!(
                "[checkpoint] skipping corrupt/invalid epoch {epoch} checkpoint \
                 {}: {e:#}",
                path.display()
            ),
        }
    }
    Ok(None)
}

/// Resolve a `--resume` argument: a file is used as-is; a directory is
/// scanned for its highest-epoch `epoch_*.ckpt`.
pub fn resolve_resume_path(path: impl AsRef<Path>) -> anyhow::Result<PathBuf> {
    let p = path.as_ref();
    if p.is_dir() {
        latest_in_dir(p)?.ok_or_else(|| {
            anyhow::anyhow!("{}: no epoch_*.ckpt checkpoints found", p.display())
        })
    } else if p.is_file() {
        Ok(p.to_path_buf())
    } else {
        anyhow::bail!("{}: resume path does not exist", p.display())
    }
}

// ---------------------------------------------------------------------------
// container
// ---------------------------------------------------------------------------

fn kind_name(kind: u8) -> &'static str {
    match kind {
        KIND_TRAIN => "training checkpoint",
        KIND_MODEL => "model",
        _ => "unknown",
    }
}

fn write_container(kind: u8, payload: &[u8], path: &Path) -> anyhow::Result<()> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32::hash(payload).to_le_bytes());
    out.extend_from_slice(MAGIC_END);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    // Temp-write + fsync + rename: a crash mid-save can never leave a torn
    // file under the final name, and the data blocks are durable BEFORE the
    // rename becomes visible (rename alone may be reordered ahead of the
    // data writes on a power loss, which would leave a corrupt file under
    // the final name — the exact failure checkpointing exists to survive).
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&out)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn read_container(kind: u8, path: &Path) -> anyhow::Result<Vec<u8>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("{}: cannot read checkpoint: {e}", path.display()))?;
    anyhow::ensure!(
        bytes.len() >= HEADER_LEN + TRAILER_LEN,
        "{}: too short to be a checkpoint ({} bytes)",
        path.display(),
        bytes.len()
    );
    // Byte-range accessor: a typed "truncated" error instead of a slice
    // panic when an offset is out of range. The length checks above and
    // below dominate every use, but checkpoint bytes are untrusted input —
    // decode must fail with context (hydra-lint R2 bans raw range
    // indexing on this path).
    let field = |lo: usize, hi: usize, what: &str| -> anyhow::Result<&[u8]> {
        bytes.get(lo..hi).ok_or_else(|| {
            anyhow::anyhow!(
                "{}: truncated checkpoint: {what} needs bytes {lo}..{hi}, file has {}",
                path.display(),
                bytes.len()
            )
        })
    };
    anyhow::ensure!(
        field(0, 4, "magic")? == MAGIC,
        "{}: not a hydra-mtp checkpoint (bad magic)",
        path.display()
    );
    let version = u32::from_le_bytes(arr4(field(4, 8, "version")?));
    anyhow::ensure!(
        version == VERSION,
        "{}: unsupported checkpoint version {version} (this build reads v{VERSION})",
        path.display()
    );
    let got_kind = bytes[8];
    anyhow::ensure!(
        got_kind == kind,
        "{}: file is a {} (kind {got_kind}), expected a {} (kind {kind})",
        path.display(),
        kind_name(got_kind),
        kind_name(kind)
    );
    let plen = u64::from_le_bytes(arr8(field(9, 17, "payload length")?));
    anyhow::ensure!(
        plen == (bytes.len() - HEADER_LEN - TRAILER_LEN) as u64,
        "{}: truncated or oversized checkpoint ({} payload bytes recorded, {} present)",
        path.display(),
        plen,
        bytes.len() - HEADER_LEN - TRAILER_LEN
    );
    let plen = plen as usize;
    let payload = field(HEADER_LEN, HEADER_LEN + plen, "payload")?;
    let crc_stored =
        u32::from_le_bytes(arr4(field(HEADER_LEN + plen, HEADER_LEN + plen + 4, "checksum")?));
    anyhow::ensure!(
        field(HEADER_LEN + plen + 4, bytes.len(), "trailing magic")? == MAGIC_END,
        "{}: bad trailing magic",
        path.display()
    );
    let crc = crc32::hash(payload);
    anyhow::ensure!(
        crc == crc_stored,
        "{}: checkpoint checksum mismatch (stored {crc_stored:#010x}, computed \
         {crc:#010x}) — the file is corrupt, refusing to load",
        path.display()
    );
    // Return the payload in place (drop trailer, shift off the header)
    // instead of copying it: checkpoints hold full model + optimizer state,
    // and a second transient copy doubles peak memory during restore.
    let mut bytes = bytes;
    bytes.truncate(HEADER_LEN + plen);
    bytes.drain(..HEADER_LEN);
    Ok(bytes)
}

// ---------------------------------------------------------------------------
// byte-level primitives
// ---------------------------------------------------------------------------

/// Fixed-width array from an exactly-sized slice, by scalar indexing — no
/// `try_into().unwrap()` on the untrusted-input decode path (hydra-lint R2).
/// Callers pass slices whose length the byte-range accessors already proved.
fn arr4(b: &[u8]) -> [u8; 4] {
    [b[0], b[1], b[2], b[3]]
}

fn arr8(b: &[u8]) -> [u8; 8] {
    [b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]
}

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// f64 by bit pattern: NaN / infinity round-trip exactly.
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn i32s(&mut self, v: &[i32]) {
        self.u64(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.pos,
            "checkpoint payload truncated: need {n} bytes at offset {}, {} remain",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = self.buf.get(self.pos..self.pos + n).ok_or_else(|| {
            anyhow::anyhow!("checkpoint payload truncated at offset {}", self.pos)
        })?;
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(arr4(self.take(4)?)))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(arr8(self.take(8)?)))
    }
    /// Length/count field: bounded so a corrupt length cannot trigger a
    /// huge allocation before the next bounds check.
    fn usize(&mut self) -> anyhow::Result<usize> {
        let v = self.u64()?;
        anyhow::ensure!(
            v <= (1 << 40),
            "checkpoint length field {v} is implausibly large (corrupt file?)"
        );
        Ok(v as usize)
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> anyhow::Result<String> {
        let n = self.usize()?;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }
    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.usize()?;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(arr4(c))).collect())
    }
    fn i32s(&mut self) -> anyhow::Result<Vec<i32>> {
        let n = self.usize()?;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(arr4(c))).collect())
    }
    /// Every byte must be consumed; trailing garbage means a reader/writer
    /// mismatch even when the CRC is intact (e.g. a hand-edited file).
    fn finish(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "checkpoint payload has {} trailing bytes after decoding",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// typed sections
// ---------------------------------------------------------------------------

fn enc_meta(e: &mut Enc, m: &LeafMeta) {
    e.str(&m.name);
    e.u64(m.shape.len() as u64);
    for &d in &m.shape {
        e.u64(d as u64);
    }
    e.u8(match m.dtype {
        DType::F32 => 0,
        DType::I32 => 1,
    });
    match &m.init {
        None => e.u8(0),
        Some(Init::Zeros) => e.u8(1),
        Some(Init::Lecun { fan_in }) => {
            e.u8(2);
            e.u64(*fan_in as u64);
        }
        Some(Init::Normal { scale }) => {
            e.u8(3);
            e.f64(*scale);
        }
    }
}

fn dec_meta(d: &mut Dec) -> anyhow::Result<LeafMeta> {
    let name = d.str()?;
    let ndim = d.usize()?;
    anyhow::ensure!(ndim <= 8, "leaf {name}: implausible rank {ndim}");
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(d.usize()?);
    }
    let dtype = match d.u8()? {
        0 => DType::F32,
        1 => DType::I32,
        other => anyhow::bail!("leaf {name}: unknown dtype tag {other}"),
    };
    let init = match d.u8()? {
        0 => None,
        1 => Some(Init::Zeros),
        2 => Some(Init::Lecun { fan_in: d.usize()? }),
        3 => Some(Init::Normal { scale: d.f64()? }),
        other => anyhow::bail!("leaf {name}: unknown init tag {other}"),
    };
    Ok(LeafMeta { name, shape, dtype, init })
}

fn enc_tensor(e: &mut Enc, t: &Tensor) {
    e.u64(t.shape.len() as u64);
    for &d in &t.shape {
        e.u64(d as u64);
    }
    match t.dtype() {
        DType::F32 => {
            e.u8(0);
            e.f32s(t.as_f32());
        }
        DType::I32 => {
            e.u8(1);
            e.i32s(t.as_i32());
        }
    }
}

fn dec_tensor(d: &mut Dec) -> anyhow::Result<Tensor> {
    let ndim = d.usize()?;
    anyhow::ensure!(ndim <= 8, "tensor: implausible rank {ndim}");
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(d.usize()?);
    }
    let expected = crate::tensor::numel(&shape);
    match d.u8()? {
        0 => {
            let data = d.f32s()?;
            anyhow::ensure!(
                data.len() == expected,
                "tensor shape {shape:?} expects {expected} elements, payload has {}",
                data.len()
            );
            Ok(Tensor::from_f32(&shape, data))
        }
        1 => {
            let data = d.i32s()?;
            anyhow::ensure!(
                data.len() == expected,
                "tensor shape {shape:?} expects {expected} elements, payload has {}",
                data.len()
            );
            Ok(Tensor::from_i32(&shape, data))
        }
        other => anyhow::bail!("unknown tensor dtype tag {other}"),
    }
}

fn enc_params(e: &mut Enc, p: &ParamSet) {
    e.u64(p.len() as u64);
    for (m, t) in p.metas().iter().zip(&p.tensors) {
        enc_meta(e, m);
        enc_tensor(e, t);
    }
}

fn dec_params(d: &mut Dec) -> anyhow::Result<ParamSet> {
    let n = d.usize()?;
    anyhow::ensure!(n <= 100_000, "implausible parameter leaf count {n}");
    let mut metas = Vec::with_capacity(n);
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        metas.push(dec_meta(d)?);
        tensors.push(dec_tensor(d)?);
    }
    ParamSet::from_parts(metas, tensors)
}

fn enc_opt(e: &mut Enc, s: &AdamWState) {
    e.u64(s.step);
    e.u64(s.m.len() as u64);
    for m in &s.m {
        e.f32s(m);
    }
    e.u64(s.v.len() as u64);
    for v in &s.v {
        e.f32s(v);
    }
}

fn dec_opt(d: &mut Dec) -> anyhow::Result<AdamWState> {
    let step = d.u64()?;
    let nm = d.usize()?;
    anyhow::ensure!(nm <= 100_000, "implausible moment leaf count {nm}");
    let mut m = Vec::with_capacity(nm);
    for _ in 0..nm {
        m.push(d.f32s()?);
    }
    let nv = d.usize()?;
    anyhow::ensure!(nv == nm, "optimizer state has {nm} first moments but {nv} second");
    let mut v = Vec::with_capacity(nv);
    for _ in 0..nv {
        v.push(d.f32s()?);
    }
    Ok(AdamWState { m, v, step })
}

fn enc_heads(e: &mut Enc, h: &Heads) {
    match h {
        Heads::Shared(b) => {
            e.u8(0);
            enc_params(e, b);
        }
        Heads::PerDataset(m) => {
            e.u8(1);
            e.u64(m.len() as u64);
            for (d, b) in m {
                e.str(&d.name());
                enc_params(e, b);
            }
        }
    }
}

fn dec_heads(d: &mut Dec) -> anyhow::Result<Heads> {
    match d.u8()? {
        0 => Ok(Heads::Shared(dec_params(d)?)),
        1 => {
            let n = d.usize()?;
            anyhow::ensure!(n <= 100_000, "implausible head count {n}");
            let mut map = BTreeMap::new();
            for _ in 0..n {
                let name = d.str()?;
                let branch = dec_params(d)?;
                let id = DatasetId::from_name(&name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "checkpoint head '{name}' refers to a task not registered in \
                         this process; register the same custom tasks the writer used \
                         (TaskRegistry::global().register) before loading"
                    )
                })?;
                map.insert(id, branch);
            }
            Ok(Heads::PerDataset(map))
        }
        other => anyhow::bail!("unknown heads tag {other}"),
    }
}

fn enc_model(e: &mut Enc, m: &TrainedModel) {
    e.str(&m.name);
    enc_params(e, &m.encoder);
    enc_heads(e, &m.heads);
}

fn dec_model(d: &mut Dec) -> anyhow::Result<TrainedModel> {
    let name = d.str()?;
    let encoder = dec_params(d)?;
    let heads = dec_heads(d)?;
    Ok(TrainedModel { name, encoder, heads })
}

fn enc_duration(e: &mut Enc, d: Duration) {
    e.u64(d.as_secs());
    e.u32(d.subsec_nanos());
}

fn dec_duration(d: &mut Dec) -> anyhow::Result<Duration> {
    let secs = d.u64()?;
    let nanos = d.u32()?;
    anyhow::ensure!(nanos < 1_000_000_000, "bad duration nanos {nanos}");
    Ok(Duration::new(secs, nanos))
}

fn enc_log(e: &mut Enc, log: &RunLog) {
    e.str(&log.model_name);
    e.u64(log.epochs.len() as u64);
    for ep in &log.epochs {
        e.u64(ep.epoch as u64);
        e.u64(ep.steps as u64);
        e.u64(ep.skipped_batches as u64);
        e.f64(ep.train_loss);
        e.f64(ep.mae_e);
        e.f64(ep.mae_f);
        e.f64(ep.val_loss);
        enc_duration(e, ep.time_total);
        enc_duration(e, ep.time_data);
        enc_duration(e, ep.time_exec);
        enc_duration(e, ep.time_comm);
        enc_duration(e, ep.time_opt);
        e.u64(ep.coverage.len() as u64);
        for c in &ep.coverage {
            e.str(&c.dataset);
            e.u64(c.planned as u64);
            e.u64(c.used as u64);
            e.f64(c.step_ms);
        }
    }
}

fn dec_log(d: &mut Dec) -> anyhow::Result<RunLog> {
    let model_name = d.str()?;
    let n = d.usize()?;
    anyhow::ensure!(n <= 10_000_000, "implausible epoch count {n}");
    let mut epochs = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let epoch = d.usize()?;
        let steps = d.usize()?;
        let skipped_batches = d.usize()?;
        let train_loss = d.f64()?;
        let mae_e = d.f64()?;
        let mae_f = d.f64()?;
        let val_loss = d.f64()?;
        let time_total = dec_duration(d)?;
        let time_data = dec_duration(d)?;
        let time_exec = dec_duration(d)?;
        let time_comm = dec_duration(d)?;
        let time_opt = dec_duration(d)?;
        let nc = d.usize()?;
        anyhow::ensure!(nc <= 100_000, "implausible coverage count {nc}");
        let mut coverage = Vec::with_capacity(nc.min(64));
        for _ in 0..nc {
            coverage.push(Coverage {
                dataset: d.str()?,
                planned: d.usize()?,
                used: d.usize()?,
                step_ms: d.f64()?,
            });
        }
        epochs.push(EpochMetrics {
            epoch,
            steps,
            skipped_batches,
            train_loss,
            mae_e,
            mae_f,
            val_loss,
            time_total,
            time_data,
            time_exec,
            time_comm,
            time_opt,
            coverage,
        });
    }
    Ok(RunLog { model_name, epochs })
}

// ---------------------------------------------------------------------------
// tests (engine-free; the end-to-end resume tests live in
// rust/tests/integration_checkpoint.rs)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{Init, LeafMeta};
    use std::sync::Arc;

    fn metas() -> Arc<Vec<LeafMeta>> {
        Arc::new(vec![
            LeafMeta {
                name: "branch.trunk.w1".into(),
                shape: vec![4, 8],
                dtype: DType::F32,
                init: Some(Init::Lecun { fan_in: 4 }),
            },
            LeafMeta {
                name: "encoder.embed".into(),
                shape: vec![10, 8],
                dtype: DType::F32,
                init: Some(Init::Normal { scale: 0.5 }),
            },
        ])
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("hydra_mtp_ckpt_unit");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}.ckpt", std::process::id()))
    }

    #[test]
    fn model_roundtrips_every_leaf_bit_for_bit() {
        let p = ParamSet::init(&metas(), 42);
        let model = TrainedModel {
            name: "unit".into(),
            encoder: p.subset("encoder."),
            heads: Heads::Shared(p.subset("branch.")),
        };
        let path = tmp("model_rt");
        save_model(&model, &path).unwrap();
        let back = load_model(&path).unwrap();
        assert_eq!(back.name, "unit");
        assert_eq!(back.encoder.tensors, model.encoder.tensors);
        match (&back.heads, &model.heads) {
            (Heads::Shared(a), Heads::Shared(b)) => assert_eq!(a.tensors, b.tensors),
            _ => panic!("heads kind changed in roundtrip"),
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn crc_rejects_any_payload_bit_flip() {
        let p = ParamSet::init(&metas(), 7);
        let model = TrainedModel {
            name: "crc".into(),
            encoder: p.subset("encoder."),
            heads: Heads::Shared(p.subset("branch.")),
        };
        let path = tmp("crc");
        save_model(&model, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_model(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checksum") || msg.contains("truncated") || msg.contains("implausible"),
            "corruption must be loudly rejected, got: {msg}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_kind_magic_and_version_are_rejected() {
        let p = ParamSet::init(&metas(), 3);
        let model = TrainedModel {
            name: "kind".into(),
            encoder: p.subset("encoder."),
            heads: Heads::Shared(p.subset("branch.")),
        };
        let path = tmp("kind");
        save_model(&model, &path).unwrap();
        // A model file is not a training checkpoint.
        let err = load_train(&path).unwrap_err();
        assert!(format!("{err}").contains("kind"), "{err}");
        // Bad magic.
        std::fs::write(&path, b"not a checkpoint at all, just some bytes padding").unwrap();
        let err = load_model(&path).unwrap_err();
        assert!(format!("{err}").contains("magic"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_file_is_rejected() {
        let p = ParamSet::init(&metas(), 9);
        let model = TrainedModel {
            name: "trunc".into(),
            encoder: p.subset("encoder."),
            heads: Heads::Shared(p.subset("branch.")),
        };
        let path = tmp("trunc");
        save_model(&model, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn epoch_path_and_latest_in_dir() {
        let dir = std::env::temp_dir()
            .join(format!("hydra_mtp_ckpt_dir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(latest_in_dir(&dir).unwrap().is_none());
        for n in [1usize, 3, 2] {
            std::fs::write(epoch_path(&dir, n), b"x").unwrap();
        }
        std::fs::write(dir.join("not_a_ckpt.txt"), b"x").unwrap();
        let latest = latest_in_dir(&dir).unwrap().unwrap();
        assert_eq!(latest, epoch_path(&dir, 3));
        assert_eq!(resolve_resume_path(&dir).unwrap(), epoch_path(&dir, 3));
        std::fs::remove_dir_all(dir).ok();
    }

    fn tiny_train_ckpt(epochs_done: usize) -> TrainCheckpoint {
        let p = ParamSet::init(&metas(), 11);
        TrainCheckpoint {
            mode: "mtl-par".into(),
            train_seed: 1,
            config_fingerprint: "fp".into(),
            epochs_done,
            stopped: false,
            stopper_best: f64::INFINITY,
            stopper_bad_epochs: 0,
            model: TrainedModel {
                name: "valid-scan".into(),
                encoder: p.subset("encoder."),
                heads: Heads::Shared(p.subset("branch.")),
            },
            opt_encoder: AdamWState { m: vec![], v: vec![], step: 0 },
            opt_heads: OptHeads::Shared(AdamWState { m: vec![], v: vec![], step: 0 }),
            log: RunLog {
                model_name: "valid-scan".into(),
                epochs: (0..epochs_done).map(|i| EpochMetrics { epoch: i, ..Default::default() }).collect(),
            },
            comm_global: 0,
            comm_head: 0,
        }
    }

    #[test]
    fn latest_valid_in_dir_skips_corrupt_and_truncated_files() {
        let dir = std::env::temp_dir()
            .join(format!("hydra_mtp_ckpt_valid_dir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(latest_valid_in_dir(&dir).unwrap().is_none());

        // Epochs 1..=3 written; 3 corrupted (bit flip), 2 truncated — the
        // CRC scan must fall back to epoch 1.
        for n in 1..=3usize {
            save_train(&tiny_train_ckpt(n), epoch_path(&dir, n)).unwrap();
        }
        crate::fault::corrupt_file(&epoch_path(&dir, 3)).unwrap();
        let bytes = std::fs::read(epoch_path(&dir, 2)).unwrap();
        std::fs::write(epoch_path(&dir, 2), &bytes[..bytes.len() / 2]).unwrap();

        // The unvalidated scan still reports epoch 3 (kept that way on
        // purpose: `latest_in_dir` is the cheap path)...
        assert_eq!(latest_in_dir(&dir).unwrap().unwrap(), epoch_path(&dir, 3));
        // ...but the validated scan lands on the intact epoch 1.
        assert_eq!(latest_valid_in_dir(&dir).unwrap().unwrap(), epoch_path(&dir, 1));

        // Corrupt the survivor too: no valid checkpoint remains.
        crate::fault::corrupt_file(&epoch_path(&dir, 1)).unwrap();
        assert!(latest_valid_in_dir(&dir).unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }
}
