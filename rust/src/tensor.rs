//! Host-side tensor type used for marshalling between the coordinator and
//! the PJRT runtime, and for all L3-side numeric state (parameters,
//! gradients, optimizer moments).
//!
//! Only the two dtypes that appear in the AOT artifacts exist: f32 and i32.

use crate::runtime::pjrt as xla;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(name: &str) -> anyhow::Result<DType> {
        match name {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype: {other}"),
        }
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; numel(shape)]) }
    }

    pub fn zeros_i32(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: TensorData::I32(vec![0; numel(shape)]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            TensorData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    pub fn as_i32_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            TensorData::I32(v) => v,
            TensorData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// First element as f64 (for scalar outputs like loss / mae).
    pub fn item(&self) -> f64 {
        match &self.data {
            TensorData::F32(v) => v[0] as f64,
            TensorData::I32(v) => v[0] as f64,
        }
    }

    /// L2 norm (f32 tensors).
    pub fn norm(&self) -> f64 {
        self.as_f32().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Convert to an xla literal for PJRT execution.
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        match &self.data {
            TensorData::F32(v) => Self::literal_f32(&self.shape, v),
            TensorData::I32(v) => Self::literal_i32(&self.shape, v),
        }
    }

    /// Literal built directly from a borrowed f32 slice: the zero-clone
    /// marshal path. Callers (e.g. `GraphBatch::field_literal`) hand their
    /// buffers in place instead of cloning them into an owning `Tensor`
    /// first; the only copy left is the one into the literal itself.
    pub fn literal_f32(shape: &[usize], data: &[f32]) -> anyhow::Result<xla::Literal> {
        anyhow::ensure!(numel(shape) == data.len(), "shape/data mismatch");
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// i32 counterpart of [`Self::literal_f32`].
    pub fn literal_i32(shape: &[usize], data: &[i32]) -> anyhow::Result<xla::Literal> {
        anyhow::ensure!(numel(shape) == data.len(), "shape/data mismatch");
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Build from an xla literal (f32 or i32 arrays).
    pub fn from_literal(lit: &xla::Literal) -> anyhow::Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::from_f32(&dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::from_i32(&dims, lit.to_vec::<i32>()?)),
            other => anyhow::bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Serialize to JSON (used by checkpoints' small tensors and configs).
    pub fn to_json(&self) -> Json {
        let shape: Vec<Json> = self.shape.iter().map(|&d| Json::Int(d as i64)).collect();
        match &self.data {
            TensorData::F32(v) => Json::obj(vec![
                ("shape", Json::Array(shape)),
                ("dtype", Json::str("f32")),
                ("data", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
            ]),
            TensorData::I32(v) => Json::obj(vec![
                ("shape", Json::Array(shape)),
                ("dtype", Json::str("i32")),
                ("data", Json::Array(v.iter().map(|&x| Json::Int(x as i64)).collect())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Tensor> {
        let shape: Vec<usize> = j
            .get("shape")
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("tensor json missing shape"))?
            .iter()
            .map(|v| v.as_i64().unwrap_or(0) as usize)
            .collect();
        let data = j.get("data").as_array().ok_or_else(|| anyhow::anyhow!("missing data"))?;
        match j.get("dtype").as_str() {
            Some("f32") => Ok(Tensor::from_f32(
                &shape,
                data.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect(),
            )),
            Some("i32") => Ok(Tensor::from_i32(
                &shape,
                data.iter().map(|v| v.as_i64().unwrap_or(0) as i32).collect(),
            )),
            other => anyhow::bail!("bad dtype {other:?}"),
        }
    }
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(if shape.is_empty() { 1 } else { 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_handles_scalar() {
        assert_eq!(numel(&[]), 1);
        assert_eq!(numel(&[2, 3]), 6);
        assert_eq!(numel(&[0, 3]), 0);
    }

    #[test]
    fn construction_checks_shape() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.numel(), 4);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn construction_rejects_bad_len() {
        Tensor::from_f32(&[3], vec![1.0]);
    }

    #[test]
    fn json_roundtrip() {
        let t = Tensor::from_f32(&[2], vec![1.5, -2.5]);
        let back = Tensor::from_json(&t.to_json()).unwrap();
        assert_eq!(t, back);
        let ti = Tensor::from_i32(&[2, 1], vec![7, -9]);
        let backi = Tensor::from_json(&ti.to_json()).unwrap();
        assert_eq!(ti, backi);
    }

    #[test]
    fn literal_from_slice_matches_owned_route() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let owned = t.to_literal().unwrap();
        let borrowed = Tensor::literal_f32(&[2, 2], t.as_f32()).unwrap();
        assert_eq!(
            owned.array_shape().unwrap().dims(),
            borrowed.array_shape().unwrap().dims()
        );
        assert_eq!(owned.to_vec::<f32>().unwrap(), borrowed.to_vec::<f32>().unwrap());

        let i = Tensor::literal_i32(&[3], &[7, 8, 9]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8, 9]);

        assert!(Tensor::literal_f32(&[3], &[1.0]).is_err());
        assert!(Tensor::literal_i32(&[2, 2], &[1]).is_err());
    }

    #[test]
    fn item_and_norm() {
        let t = Tensor::from_f32(&[2], vec![3.0, 4.0]);
        assert_eq!(t.item(), 3.0);
        assert!((t.norm() - 5.0).abs() < 1e-12);
    }
}
