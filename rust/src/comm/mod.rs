//! Communication layer: shared-memory collectives over rank threads and the
//! 2D DeviceMesh (global encoder group x per-head sub-groups) that carries
//! the paper's multi-task-parallel + DDP gradient synchronization.

pub mod collectives;
pub mod halo;
pub mod mesh;
pub mod overlap;

pub use collectives::{run_group, run_group_with, Comm, CommError, CommStats, MemberGuard};
pub use halo::{segment_owner, HaloPlan};
pub use mesh::{
    build_mesh, build_mesh_with_timeout, build_ragged_mesh_with_timeout, MeshRank, MeshShape,
    RaggedMeshRank, RaggedShape,
};
pub use overlap::{BucketPlan, OverlapReducer, OverlapSink, Segment};
