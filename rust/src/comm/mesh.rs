//! DeviceMesh: the 2D process organization of the paper (Figure 3, right).
//!
//! Ranks form an `num_heads x replicas` mesh:
//!   - one **global group** over all ranks synchronizes the shared MPNN
//!     encoder gradients (the paper's "one global group ... DDP"),
//!   - `num_heads` **head sub-groups** of `replicas` ranks each synchronize
//!     one MTL output head's gradients ("N sub-process groups, each with M
//!     processes, perform local DDPs").
//!
//! This mirrors `torch.distributed.DeviceMesh` with (head, replica) axes.

use std::time::Duration;

use crate::comm::collectives::{Comm, DEFAULT_TIMEOUT};

/// Mesh geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeshShape {
    pub num_heads: usize,
    pub replicas: usize,
}

impl MeshShape {
    pub fn world_size(&self) -> usize {
        self.num_heads * self.replicas
    }

    /// rank -> (head, replica). Ranks are laid out head-major, matching the
    /// paper's contiguous sub-groups.
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.world_size());
        (rank / self.replicas, rank % self.replicas)
    }

    pub fn rank_of(&self, head: usize, replica: usize) -> usize {
        assert!(head < self.num_heads && replica < self.replicas);
        head * self.replicas + replica
    }
}

/// One rank's view of the mesh: its coordinates plus communicator handles
/// for the global group and its head sub-group.
pub struct MeshRank {
    pub rank: usize,
    pub head: usize,
    pub replica: usize,
    pub shape: MeshShape,
    /// All ranks: encoder-gradient DDP.
    pub global: Comm,
    /// This rank's head sub-group: head-gradient local DDP.
    pub head_group: Comm,
}

/// Build every rank's mesh view. The returned vec is indexed by rank and is
/// meant to be moved into the rank threads.
pub fn build_mesh(shape: MeshShape) -> Vec<MeshRank> {
    build_mesh_with_timeout(shape, DEFAULT_TIMEOUT)
}

/// As [`build_mesh`] with an explicit collective timeout on every group.
/// Head sub-groups are labeled with GLOBAL ranks, so a
/// [`CommError::RankFailure`](crate::comm::collectives::CommError) raised
/// inside a head group still names the rank an operator would restart.
pub fn build_mesh_with_timeout(shape: MeshShape, timeout: Duration) -> Vec<MeshRank> {
    let world = shape.world_size();
    assert!(world > 0);
    let global = Comm::group_with(world, timeout, None);
    let mut head_groups: Vec<Vec<Comm>> = (0..shape.num_heads)
        .map(|h| {
            let labels = (0..shape.replicas).map(|r| shape.rank_of(h, r)).collect();
            Comm::group_with(shape.replicas, timeout, Some(labels))
        })
        .collect();

    let mut out = Vec::with_capacity(world);
    for (rank, global_comm) in global.into_iter().enumerate() {
        let (head, replica) = shape.coords(rank);
        // Pull this rank's handle out of its head group (replica-indexed).
        let head_comm = std::mem::replace(
            &mut head_groups[head][replica],
            // Placeholder that is never used again.
            Comm::group(1).pop().unwrap(),
        );
        out.push(MeshRank {
            rank,
            head,
            replica,
            shape,
            global: global_comm,
            head_group: head_comm,
        });
    }
    out
}

/// Ragged mesh geometry for the elastic head scheduler: head `h` owns
/// `sizes[h]` contiguous ranks (head-major layout, like [`MeshShape`] with
/// per-head widths). Sizes are fixed within an epoch; the elastic trainer
/// rebuilds the mesh at epoch boundaries from measured per-head step costs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RaggedShape {
    sizes: Vec<usize>,
    /// `starts[h]` = first global rank of head `h`; one extra trailing
    /// entry holds the world size.
    starts: Vec<usize>,
}

impl RaggedShape {
    /// Every head needs at least one rank.
    pub fn new(sizes: Vec<usize>) -> anyhow::Result<RaggedShape> {
        anyhow::ensure!(!sizes.is_empty(), "ragged mesh needs at least one head");
        anyhow::ensure!(
            sizes.iter().all(|&s| s >= 1),
            "every head sub-group needs at least one rank (got {sizes:?})"
        );
        let mut starts = Vec::with_capacity(sizes.len() + 1);
        let mut acc = 0usize;
        for &s in &sizes {
            starts.push(acc);
            acc += s;
        }
        starts.push(acc);
        Ok(RaggedShape { sizes, starts })
    }

    pub fn num_heads(&self) -> usize {
        self.sizes.len()
    }

    pub fn world_size(&self) -> usize {
        *self.starts.last().expect("starts is never empty")
    }

    pub fn head_size(&self, head: usize) -> usize {
        self.sizes[head]
    }

    pub fn head_sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// First global rank of `head` — the sub-group's broadcast root for
    /// checkpoint gathers.
    pub fn head_root(&self, head: usize) -> usize {
        self.starts[head]
    }

    pub fn rank_of(&self, head: usize, replica: usize) -> usize {
        assert!(head < self.num_heads() && replica < self.sizes[head]);
        self.starts[head] + replica
    }

    /// rank -> (head, replica).
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.world_size());
        // `starts` is strictly increasing, so the owning head is the last
        // start at or below `rank`.
        let head = self.starts.partition_point(|&s| s <= rank) - 1;
        (head, rank - self.starts[head])
    }
}

/// One rank's view of a ragged mesh (elastic MTL-par): coordinates plus the
/// global and head-sub-group communicator handles.
pub struct RaggedMeshRank {
    pub rank: usize,
    pub head: usize,
    pub replica: usize,
    pub shape: RaggedShape,
    pub global: Comm,
    pub head_group: Comm,
}

/// As [`build_mesh_with_timeout`] for a ragged shape: one global group over
/// all ranks plus one sub-group per head sized `shape.head_size(h)`, each
/// labeled with GLOBAL ranks for failure reporting.
pub fn build_ragged_mesh_with_timeout(
    shape: &RaggedShape,
    timeout: Duration,
) -> Vec<RaggedMeshRank> {
    let world = shape.world_size();
    let global = Comm::group_with(world, timeout, None);
    let mut head_groups: Vec<Vec<Comm>> = (0..shape.num_heads())
        .map(|h| {
            let labels = (0..shape.head_size(h)).map(|r| shape.rank_of(h, r)).collect();
            Comm::group_with(shape.head_size(h), timeout, Some(labels))
        })
        .collect();

    let mut out = Vec::with_capacity(world);
    for (rank, global_comm) in global.into_iter().enumerate() {
        let (head, replica) = shape.coords(rank);
        let head_comm = std::mem::replace(
            &mut head_groups[head][replica],
            // Placeholder that is never used again.
            Comm::group(1).pop().unwrap(),
        );
        out.push(RaggedMeshRank {
            rank,
            head,
            replica,
            shape: shape.clone(),
            global: global_comm,
            head_group: head_comm,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn coords_roundtrip() {
        let shape = MeshShape { num_heads: 5, replicas: 4 };
        for rank in 0..shape.world_size() {
            let (h, r) = shape.coords(rank);
            assert_eq!(shape.rank_of(h, r), rank);
        }
    }

    #[test]
    fn subgroups_are_contiguous_head_major() {
        let shape = MeshShape { num_heads: 3, replicas: 2 };
        assert_eq!(shape.coords(0), (0, 0));
        assert_eq!(shape.coords(1), (0, 1));
        assert_eq!(shape.coords(2), (1, 0));
        assert_eq!(shape.coords(5), (2, 1));
    }

    #[test]
    fn head_groups_reduce_independently_global_reduces_all() {
        let shape = MeshShape { num_heads: 2, replicas: 2 };
        let ranks = build_mesh(shape);
        let handles: Vec<_> = ranks
            .into_iter()
            .map(|mr| {
                thread::spawn(move || {
                    // Head-group mean of the rank id: head 0 has ranks {0,1}
                    // -> 0.5; head 1 has ranks {2,3} -> 2.5.
                    let mut head_val = vec![mr.rank as f32];
                    mr.head_group.allreduce_mean(&mut head_val).unwrap();
                    // Global mean of the rank id: 1.5.
                    let mut global_val = vec![mr.rank as f32];
                    mr.global.allreduce_mean(&mut global_val).unwrap();
                    (mr.head, head_val[0], global_val[0])
                })
            })
            .collect();
        for h in handles {
            let (head, head_mean, global_mean) = h.join().unwrap();
            let expected = if head == 0 { 0.5 } else { 2.5 };
            assert!((head_mean - expected).abs() < 1e-6);
            assert!((global_mean - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn ragged_coords_roundtrip_and_roots() {
        let shape = RaggedShape::new(vec![3, 1, 2]).unwrap();
        assert_eq!(shape.world_size(), 6);
        assert_eq!(shape.num_heads(), 3);
        for rank in 0..shape.world_size() {
            let (h, r) = shape.coords(rank);
            assert_eq!(shape.rank_of(h, r), rank);
        }
        assert_eq!(shape.head_root(0), 0);
        assert_eq!(shape.head_root(1), 3);
        assert_eq!(shape.head_root(2), 4);
        assert!(RaggedShape::new(vec![2, 0]).is_err(), "zero-rank head rejected");
        assert!(RaggedShape::new(vec![]).is_err(), "empty shape rejected");
    }

    #[test]
    fn ragged_head_groups_reduce_independently() {
        let shape = RaggedShape::new(vec![2, 1]).unwrap();
        let ranks = build_ragged_mesh_with_timeout(&shape, DEFAULT_TIMEOUT);
        let handles: Vec<_> = ranks
            .into_iter()
            .map(|mr| {
                thread::spawn(move || {
                    // Head 0 owns ranks {0,1} -> mean 0.5; head 1 owns {2}
                    // -> mean 2.0; the global mean over {0,1,2} is 1.0.
                    let mut head_val = vec![mr.rank as f32];
                    mr.head_group.allreduce_mean(&mut head_val).unwrap();
                    let mut global_val = vec![mr.rank as f32];
                    mr.global.allreduce_mean(&mut global_val).unwrap();
                    (mr.head, mr.replica, head_val[0], global_val[0])
                })
            })
            .collect();
        for h in handles {
            let (head, replica, head_mean, global_mean) = h.join().unwrap();
            let expected = if head == 0 { 0.5 } else { 2.0 };
            assert!((head_mean - expected).abs() < 1e-6);
            assert!((global_mean - 1.0).abs() < 1e-6);
            if head == 1 {
                assert_eq!(replica, 0);
            }
        }
    }

    #[test]
    fn mesh_rank_metadata_consistent() {
        let shape = MeshShape { num_heads: 2, replicas: 3 };
        let ranks = build_mesh(shape);
        assert_eq!(ranks.len(), 6);
        for (i, mr) in ranks.iter().enumerate() {
            assert_eq!(mr.rank, i);
            assert_eq!((mr.head, mr.replica), shape.coords(i));
            assert_eq!(mr.global.size(), 6);
            assert_eq!(mr.head_group.size(), 3);
            assert_eq!(mr.head_group.rank_in_group, mr.replica);
            assert_eq!(mr.global.label(), i, "global group uses identity labels");
            assert_eq!(
                mr.head_group.label(),
                i,
                "head groups are labeled by GLOBAL rank for failure reporting"
            );
        }
    }
}
