//! DeviceMesh: the 2D process organization of the paper (Figure 3, right).
//!
//! Ranks form an `num_heads x replicas` mesh:
//!   - one **global group** over all ranks synchronizes the shared MPNN
//!     encoder gradients (the paper's "one global group ... DDP"),
//!   - `num_heads` **head sub-groups** of `replicas` ranks each synchronize
//!     one MTL output head's gradients ("N sub-process groups, each with M
//!     processes, perform local DDPs").
//!
//! This mirrors `torch.distributed.DeviceMesh` with (head, replica) axes.

use std::time::Duration;

use crate::comm::collectives::{Comm, DEFAULT_TIMEOUT};

/// Mesh geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeshShape {
    pub num_heads: usize,
    pub replicas: usize,
}

impl MeshShape {
    pub fn world_size(&self) -> usize {
        self.num_heads * self.replicas
    }

    /// rank -> (head, replica). Ranks are laid out head-major, matching the
    /// paper's contiguous sub-groups.
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.world_size());
        (rank / self.replicas, rank % self.replicas)
    }

    pub fn rank_of(&self, head: usize, replica: usize) -> usize {
        assert!(head < self.num_heads && replica < self.replicas);
        head * self.replicas + replica
    }
}

/// One rank's view of the mesh: its coordinates plus communicator handles
/// for the global group and its head sub-group.
pub struct MeshRank {
    pub rank: usize,
    pub head: usize,
    pub replica: usize,
    pub shape: MeshShape,
    /// All ranks: encoder-gradient DDP.
    pub global: Comm,
    /// This rank's head sub-group: head-gradient local DDP.
    pub head_group: Comm,
}

/// Build every rank's mesh view. The returned vec is indexed by rank and is
/// meant to be moved into the rank threads.
pub fn build_mesh(shape: MeshShape) -> Vec<MeshRank> {
    build_mesh_with_timeout(shape, DEFAULT_TIMEOUT)
}

/// As [`build_mesh`] with an explicit collective timeout on every group.
/// Head sub-groups are labeled with GLOBAL ranks, so a
/// [`CommError::RankFailure`](crate::comm::collectives::CommError) raised
/// inside a head group still names the rank an operator would restart.
pub fn build_mesh_with_timeout(shape: MeshShape, timeout: Duration) -> Vec<MeshRank> {
    let world = shape.world_size();
    assert!(world > 0);
    let global = Comm::group_with(world, timeout, None);
    let mut head_groups: Vec<Vec<Comm>> = (0..shape.num_heads)
        .map(|h| {
            let labels = (0..shape.replicas).map(|r| shape.rank_of(h, r)).collect();
            Comm::group_with(shape.replicas, timeout, Some(labels))
        })
        .collect();

    let mut out = Vec::with_capacity(world);
    for (rank, global_comm) in global.into_iter().enumerate() {
        let (head, replica) = shape.coords(rank);
        // Pull this rank's handle out of its head group (replica-indexed).
        let head_comm = std::mem::replace(
            &mut head_groups[head][replica],
            // Placeholder that is never used again.
            Comm::group(1).pop().unwrap(),
        );
        out.push(MeshRank {
            rank,
            head,
            replica,
            shape,
            global: global_comm,
            head_group: head_comm,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn coords_roundtrip() {
        let shape = MeshShape { num_heads: 5, replicas: 4 };
        for rank in 0..shape.world_size() {
            let (h, r) = shape.coords(rank);
            assert_eq!(shape.rank_of(h, r), rank);
        }
    }

    #[test]
    fn subgroups_are_contiguous_head_major() {
        let shape = MeshShape { num_heads: 3, replicas: 2 };
        assert_eq!(shape.coords(0), (0, 0));
        assert_eq!(shape.coords(1), (0, 1));
        assert_eq!(shape.coords(2), (1, 0));
        assert_eq!(shape.coords(5), (2, 1));
    }

    #[test]
    fn head_groups_reduce_independently_global_reduces_all() {
        let shape = MeshShape { num_heads: 2, replicas: 2 };
        let ranks = build_mesh(shape);
        let handles: Vec<_> = ranks
            .into_iter()
            .map(|mr| {
                thread::spawn(move || {
                    // Head-group mean of the rank id: head 0 has ranks {0,1}
                    // -> 0.5; head 1 has ranks {2,3} -> 2.5.
                    let mut head_val = vec![mr.rank as f32];
                    mr.head_group.allreduce_mean(&mut head_val).unwrap();
                    // Global mean of the rank id: 1.5.
                    let mut global_val = vec![mr.rank as f32];
                    mr.global.allreduce_mean(&mut global_val).unwrap();
                    (mr.head, head_val[0], global_val[0])
                })
            })
            .collect();
        for h in handles {
            let (head, head_mean, global_mean) = h.join().unwrap();
            let expected = if head == 0 { 0.5 } else { 2.5 };
            assert!((head_mean - expected).abs() < 1e-6);
            assert!((global_mean - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn mesh_rank_metadata_consistent() {
        let shape = MeshShape { num_heads: 2, replicas: 3 };
        let ranks = build_mesh(shape);
        assert_eq!(ranks.len(), 6);
        for (i, mr) in ranks.iter().enumerate() {
            assert_eq!(mr.rank, i);
            assert_eq!((mr.head, mr.replica), shape.coords(i));
            assert_eq!(mr.global.size(), 6);
            assert_eq!(mr.head_group.size(), 3);
            assert_eq!(mr.head_group.rank_in_group, mr.replica);
            assert_eq!(mr.global.label(), i, "global group uses identity labels");
            assert_eq!(
                mr.head_group.label(),
                i,
                "head groups are labeled by GLOBAL rank for failure reporting"
            );
        }
    }
}
