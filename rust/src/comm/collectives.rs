//! Shared-memory collective operations over a group of rank threads.
//!
//! The trainer's "processes" are OS threads (one per simulated GPU); a
//! `Comm` is one member's handle to a process group, with NCCL-style
//! collectives implemented as a sense-gated rendezvous: ranks accumulate
//! into a shared buffer, the last arrival finalizes, everyone copies out,
//! and the round drains before the next may begin. Numerically this is
//! exactly the averaging a ring allreduce performs; the *cost* of the ring
//! on a real fabric is priced separately by `scalesim` (same code path, a
//! virtual clock instead of a wall clock).
//!
//! The rendezvous is **failure-aware**: a member that panics or exits
//! early would otherwise leave its peers parked on the condvar forever.
//! Instead, every collective returns a typed [`CommError`]:
//!
//! * A [`MemberGuard`] dropped while armed (the rank panicked or bailed
//!   before disarming) **poisons** the group — subsequent and in-flight
//!   waiters wake immediately with [`CommError::RankFailure`] naming the
//!   dead rank's label (mesh head groups label members by GLOBAL rank, so
//!   the error always names the rank an operator would restart).
//! * Every wait carries the group's configured timeout
//!   ([`DEFAULT_TIMEOUT`], or [`Comm::group_with`]); a straggler that
//!   never arrives surfaces as [`CommError::Timeout`] instead of a hang.
//!
//! A completed round is never aborted: release waits check the
//! round-complete condition *before* the poison flag, so members that
//! already rendezvoused copy their result out even if a failure lands in
//! the same instant.
//!
//! Traffic counters record every payload so tests and the scaling study can
//! verify the paper's key claim: multi-task parallelism replaces one global
//! `P_s + N_h*P_h` allreduce with a global `P_s` allreduce plus per-head
//! local `P_h` allreduces.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Default bound on any single collective wait. Generous for real work;
/// chaos tests shrink it via [`Comm::group_with`] to keep failures fast.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(60);

/// Typed failure of a collective. Collectives never hang: a dead member
/// converts to `RankFailure`, a straggler past the group timeout to
/// `Timeout`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommError {
    /// A group member panicked or exited before completing the round. The
    /// rank is the member's *label* — the global rank for mesh groups.
    RankFailure { rank: usize },
    /// The collective did not complete within the group's timeout.
    Timeout { waited_ms: u64 },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::RankFailure { rank } => {
                write!(f, "collective failed: rank {rank} died mid-round")
            }
            CommError::Timeout { waited_ms } => {
                write!(f, "collective timed out after {waited_ms} ms")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Traffic counters of one communicator. `elems` counts every f32 moved
/// through any collective; `overlapped_elems` is the subset that moved
/// through the overlapped entry points (bucketed reductions issued from a
/// comm thread while backward still runs) — the seed's two-counter tuple
/// could not tell the bench what actually moved concurrently.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Total f32 elements moved through collectives (allreduce AND
    /// broadcast) on this communicator.
    pub elems: u64,
    /// Completed collective rounds.
    pub rounds: u64,
    /// f32 elements reduced through [`Comm::allreduce_mean_overlapped`]
    /// (always `<= elems`).
    pub overlapped_elems: u64,
}

#[derive(Default)]
struct RoundState {
    /// Per-rank contributions of the in-flight round (rank-indexed). The
    /// last arrival folds them together in RANK order, which makes the
    /// reduction a pure function of the inputs — independent of thread
    /// arrival order. (The seed accumulated in arrival order, so multi-rank
    /// runs were reproducible only to ~1e-5; checkpoint resume needs
    /// bit-identity across whole reruns.)
    parts: Vec<Vec<f64>>,
    /// Finalized round result every member copies out.
    accum: Vec<f64>,
    arrived: usize,
    departing: usize,
    /// Label of the first member known dead; set by [`Comm::poison`] /
    /// a dropped [`MemberGuard`]. Permanent: the group cannot complete
    /// another round once a member is gone.
    failed: Option<usize>,
}

struct Shared {
    size: usize,
    state: Mutex<RoundState>,
    cv: Condvar,
    /// Bound on any single collective wait.
    timeout: Duration,
    /// Per-member labels reported in [`CommError::RankFailure`]. Defaults
    /// to `0..n`; mesh head groups pass global ranks.
    labels: Vec<usize>,
    /// Total f32 elements moved through collectives (allreduce AND
    /// broadcast) on this communicator. Broadcast was not counted by the
    /// seed, which undercounted the traffic behind the paper's P_s-vs-P_h
    /// communication-volume claim once checkpoint restores entered the mix.
    reduced_elems: AtomicU64,
    /// Number of collective rounds completed.
    rounds: AtomicU64,
    /// Subset of `reduced_elems` that moved through the overlapped entry
    /// points (see [`CommStats::overlapped_elems`]).
    overlapped_elems: AtomicU64,
}

/// Recover the guard even if a peer panicked while holding the lock: the
/// protected state is only ever mutated to a consistent point before any
/// wait, and a poisoned group is already terminal.
fn lock(shared: &Shared) -> MutexGuard<'_, RoundState> {
    shared.state.lock().unwrap_or_else(|p| p.into_inner())
}

fn poison_shared(shared: &Shared, label: usize) {
    let mut st = lock(shared);
    if st.failed.is_none() {
        st.failed = Some(label);
    }
    drop(st);
    shared.cv.notify_all();
}

/// Scope guard registering a thread as a live group member. Drop while
/// armed (panic unwind, early `?` return) poisons the group so peers get
/// [`CommError::RankFailure`] instead of hanging; call
/// [`MemberGuard::disarm`] on clean exit.
pub struct MemberGuard {
    shared: Arc<Shared>,
    label: usize,
    armed: bool,
}

impl MemberGuard {
    /// Mark this member's clean exit: dropping no longer poisons.
    pub fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for MemberGuard {
    fn drop(&mut self) {
        if self.armed {
            poison_shared(&self.shared, self.label);
        }
    }
}

/// One member's handle to a process group.
#[derive(Clone)]
pub struct Comm {
    shared: Arc<Shared>,
    pub rank_in_group: usize,
}

impl Comm {
    /// Create a group of `n` communicator handles (one per member thread)
    /// with the [`DEFAULT_TIMEOUT`] and identity labels.
    pub fn group(n: usize) -> Vec<Comm> {
        Comm::group_with(n, DEFAULT_TIMEOUT, None)
    }

    /// As [`Comm::group`] with an explicit collective timeout and optional
    /// member labels (`labels[i]` names member `i` in failure errors —
    /// mesh head groups pass global ranks). `labels` defaults to `0..n`.
    pub fn group_with(n: usize, timeout: Duration, labels: Option<Vec<usize>>) -> Vec<Comm> {
        assert!(n > 0);
        let labels = labels.unwrap_or_else(|| (0..n).collect());
        assert_eq!(labels.len(), n, "one label per group member");
        let shared = Arc::new(Shared {
            size: n,
            state: Mutex::new(RoundState {
                parts: vec![Vec::new(); n],
                ..RoundState::default()
            }),
            cv: Condvar::new(),
            timeout,
            labels,
            reduced_elems: AtomicU64::new(0),
            rounds: AtomicU64::new(0),
            overlapped_elems: AtomicU64::new(0),
        });
        (0..n).map(|i| Comm { shared: Arc::clone(&shared), rank_in_group: i }).collect()
    }

    pub fn size(&self) -> usize {
        self.shared.size
    }

    /// This member's failure-reporting label (== `rank_in_group` unless
    /// the group was built with explicit labels).
    pub fn label(&self) -> usize {
        self.shared.labels[self.rank_in_group]
    }

    /// Register this thread as a live member: the returned guard poisons
    /// the group if dropped before [`MemberGuard::disarm`].
    pub fn member_guard(&self) -> MemberGuard {
        MemberGuard { shared: Arc::clone(&self.shared), label: self.label(), armed: true }
    }

    /// Mark this member dead (first failure wins) and wake every waiter.
    pub fn poison(&self) {
        poison_shared(&self.shared, self.label());
    }

    /// Wait on the group condvar, bounded by `deadline`.
    fn wait_deadline<'a>(
        &'a self,
        st: MutexGuard<'a, RoundState>,
        deadline: Instant,
    ) -> Result<MutexGuard<'a, RoundState>, CommError> {
        // lint:allow(nondeterministic): wall-clock bounds the failure-detection wait only
        let now = Instant::now();
        if now >= deadline {
            return Err(CommError::Timeout {
                waited_ms: self.shared.timeout.as_millis() as u64,
            });
        }
        let (guard, _timed_out) = self
            .shared
            .cv
            .wait_timeout(st, deadline - now)
            .unwrap_or_else(|p| p.into_inner());
        Ok(guard)
    }

    /// Elementwise mean across the group, in place. All members must call.
    pub fn allreduce_mean(&self, data: &mut [f32]) -> Result<(), CommError> {
        self.reduce(data, true, false)
    }

    /// Elementwise sum across the group, in place.
    pub fn allreduce_sum(&self, data: &mut [f32]) -> Result<(), CommError> {
        self.reduce(data, false, false)
    }

    /// As [`Comm::allreduce_mean`], tagged as overlapped traffic: the
    /// payload additionally counts toward [`CommStats::overlapped_elems`].
    /// Numerically and bit-for-bit identical to the untagged call — the
    /// overlap machinery (`comm::overlap`) issues its bucket reductions
    /// through here so the bench can report what moved concurrently.
    pub fn allreduce_mean_overlapped(&self, data: &mut [f32]) -> Result<(), CommError> {
        self.reduce(data, true, true)
    }

    fn reduce(&self, data: &mut [f32], mean: bool, overlapped: bool) -> Result<(), CommError> {
        let sh = &self.shared;
        if overlapped {
            sh.overlapped_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
        }
        if sh.size == 1 {
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            return Ok(());
        }
        // lint:allow(nondeterministic): deadline clock never feeds reduced values or ordering
        let deadline = Instant::now() + sh.timeout;
        let mut st = lock(sh);
        // Gate: previous round must fully drain first. A poisoned group
        // can never complete another round — fail fast before depositing.
        loop {
            if let Some(rank) = st.failed {
                return Err(CommError::RankFailure { rank });
            }
            if st.departing == 0 {
                break;
            }
            st = self.wait_deadline(st, deadline)?;
        }
        // Deposit this rank's contribution (widened to f64, which keeps DDP
        // means stable) in its own slot; the final sum happens in rank
        // order so the result is arrival-order independent.
        {
            let slot = &mut st.parts[self.rank_in_group];
            slot.clear();
            slot.extend(data.iter().map(|&x| x as f64));
        }
        st.arrived += 1;
        if st.arrived == sh.size {
            {
                let RoundState { parts, accum, .. } = &mut *st;
                accum.clear();
                accum.resize(data.len(), 0.0);
                for part in parts.iter() {
                    for (a, &x) in accum.iter_mut().zip(part.iter()) {
                        *a += x;
                    }
                }
                if mean {
                    let inv = 1.0 / sh.size as f64;
                    for a in accum.iter_mut() {
                        *a *= inv;
                    }
                }
            }
            st.arrived = 0;
            st.departing = sh.size;
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            sh.cv.notify_all();
        } else {
            // Release wait: round-complete is checked BEFORE the poison
            // flag — a round that rendezvoused is never aborted.
            loop {
                if st.departing > 0 {
                    break;
                }
                if let Some(rank) = st.failed {
                    return Err(CommError::RankFailure { rank });
                }
                st = self.wait_deadline(st, deadline)?;
            }
        }
        for (x, &a) in data.iter_mut().zip(st.accum.iter()) {
            *x = a as f32;
        }
        st.departing -= 1;
        if st.departing == 0 {
            sh.cv.notify_all();
        }
        Ok(())
    }

    /// Elementwise f64 sum across the group, in place. The full-precision
    /// sibling of [`Comm::allreduce_sum`]: payloads stay f64 end to end (no
    /// f32 round-trip), which the graph-parallel halo exchange depends on —
    /// boundary activations and gradients are exchanged mid-computation, so
    /// any rounding here would break bit-identity with the single-rank run.
    /// Folding happens in rank order like the f32 path, so the result is
    /// arrival-order independent. Counts one element per f64 into
    /// [`CommStats::elems`].
    pub fn allreduce_sum_f64(&self, data: &mut [f64]) -> Result<(), CommError> {
        let sh = &self.shared;
        if sh.size == 1 {
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            return Ok(());
        }
        // lint:allow(nondeterministic): deadline clock never feeds reduced values or ordering
        let deadline = Instant::now() + sh.timeout;
        let mut st = lock(sh);
        loop {
            if let Some(rank) = st.failed {
                return Err(CommError::RankFailure { rank });
            }
            if st.departing == 0 {
                break;
            }
            st = self.wait_deadline(st, deadline)?;
        }
        {
            let slot = &mut st.parts[self.rank_in_group];
            slot.clear();
            slot.extend_from_slice(data);
        }
        st.arrived += 1;
        if st.arrived == sh.size {
            {
                let RoundState { parts, accum, .. } = &mut *st;
                accum.clear();
                accum.resize(data.len(), 0.0);
                for part in parts.iter() {
                    for (a, &x) in accum.iter_mut().zip(part.iter()) {
                        *a += x;
                    }
                }
            }
            st.arrived = 0;
            st.departing = sh.size;
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            sh.cv.notify_all();
        } else {
            // Release wait: round-complete is checked BEFORE the poison
            // flag — a round that rendezvoused is never aborted.
            loop {
                if st.departing > 0 {
                    break;
                }
                if let Some(rank) = st.failed {
                    return Err(CommError::RankFailure { rank });
                }
                st = self.wait_deadline(st, deadline)?;
            }
        }
        data.copy_from_slice(&st.accum);
        st.departing -= 1;
        if st.departing == 0 {
            sh.cv.notify_all();
        }
        Ok(())
    }

    /// Broadcast `data` from `root` to every member, in place. The payload
    /// counts toward [`Comm::stats`] like any other collective (the seed
    /// moved the bytes but never incremented the traffic counter, so
    /// broadcast-heavy paths — checkpoint restore in particular — were
    /// invisible to the communication-volume accounting).
    pub fn broadcast(&self, root: usize, data: &mut [f32]) -> Result<(), CommError> {
        let sh = &self.shared;
        if sh.size == 1 {
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            return Ok(());
        }
        // lint:allow(nondeterministic): deadline clock never feeds broadcast payloads
        let deadline = Instant::now() + sh.timeout;
        let mut st = lock(sh);
        loop {
            if let Some(rank) = st.failed {
                return Err(CommError::RankFailure { rank });
            }
            if st.departing == 0 {
                break;
            }
            st = self.wait_deadline(st, deadline)?;
        }
        if self.rank_in_group == root {
            st.accum.clear();
            st.accum.extend(data.iter().map(|&x| x as f64));
        }
        st.arrived += 1;
        if st.arrived == sh.size {
            st.arrived = 0;
            st.departing = sh.size;
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            sh.cv.notify_all();
        } else {
            loop {
                if st.departing > 0 {
                    break;
                }
                if let Some(rank) = st.failed {
                    return Err(CommError::RankFailure { rank });
                }
                st = self.wait_deadline(st, deadline)?;
            }
        }
        // Root may have arrived last; accum is valid in either case because
        // only the root writes it and every writer-arrival precedes release.
        for (x, &a) in data.iter_mut().zip(st.accum.iter()) {
            *x = a as f32;
        }
        st.departing -= 1;
        if st.departing == 0 {
            sh.cv.notify_all();
        }
        Ok(())
    }

    /// Barrier across the group.
    pub fn barrier(&self) -> Result<(), CommError> {
        let mut unit = [0f32; 0];
        self.reduce(&mut unit, false, false)
    }

    /// Allgather of one f64 per rank (metrics aggregation).
    pub fn allgather_f64(&self, value: f64) -> Result<Vec<f64>, CommError> {
        let n = self.shared.size;
        let mut slots = vec![0f32; 2 * n];
        // Encode f64 as two f32 halves to reuse the f32 reduce path without
        // precision loss on metric magnitudes: hi = f32(value), lo = f32(value - hi).
        let hi = value as f32;
        let lo = (value - hi as f64) as f32;
        slots[2 * self.rank_in_group] = hi;
        slots[2 * self.rank_in_group + 1] = lo;
        self.allreduce_sum(&mut slots)?;
        Ok((0..n).map(|i| slots[2 * i] as f64 + slots[2 * i + 1] as f64).collect())
    }

    /// Traffic counters of this communicator (see [`CommStats`]).
    pub fn stats(&self) -> CommStats {
        CommStats {
            elems: self.shared.reduced_elems.load(Ordering::Relaxed),
            rounds: self.shared.rounds.load(Ordering::Relaxed),
            overlapped_elems: self.shared.overlapped_elems.load(Ordering::Relaxed),
        }
    }
}

/// Run `f` once per member of a fresh `n`-member group, one thread each,
/// with a [`MemberGuard`] installed — a panicking member poisons the group
/// (peers see [`CommError::RankFailure`]) and surfaces in its own slot as
/// `Err(RankFailure)` naming its rank. Uses the [`DEFAULT_TIMEOUT`].
pub fn run_group<T: Send>(
    n: usize,
    f: impl Fn(Comm) -> T + Send + Sync,
) -> Vec<Result<T, CommError>> {
    run_group_with(n, DEFAULT_TIMEOUT, f)
}

/// As [`run_group`] with an explicit collective timeout.
pub fn run_group_with<T: Send>(
    n: usize,
    timeout: Duration,
    f: impl Fn(Comm) -> T + Send + Sync,
) -> Vec<Result<T, CommError>> {
    let comms = Comm::group_with(n, timeout, None);
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                s.spawn(move || {
                    let guard = c.member_guard();
                    let out = f(c);
                    guard.disarm();
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(rank, h)| h.join().map_err(|_| CommError::RankFailure { rank }))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unwrap both layers: the thread must not panic and the closure's own
    /// result is returned as-is.
    fn run_group_ok<T: Send>(n: usize, f: impl Fn(Comm) -> T + Send + Sync) -> Vec<T> {
        run_group(n, f).into_iter().map(|r| r.unwrap()).collect()
    }

    #[test]
    fn allreduce_mean_averages() {
        let results = run_group_ok(4, |c| {
            let mut data = vec![c.rank_in_group as f32; 8];
            c.allreduce_mean(&mut data).unwrap();
            data
        });
        for r in results {
            for x in r {
                assert!((x - 1.5).abs() < 1e-6); // mean of 0,1,2,3
            }
        }
    }

    #[test]
    fn allreduce_sum_sums() {
        let results = run_group_ok(3, |c| {
            let mut data = vec![1.0f32, 2.0];
            c.allreduce_sum(&mut data).unwrap();
            data
        });
        for r in results {
            assert_eq!(r, vec![3.0, 6.0]);
        }
    }

    #[test]
    fn repeated_rounds_do_not_interleave() {
        let results = run_group_ok(4, |c| {
            let mut out = Vec::new();
            for round in 0..50 {
                let mut data = vec![(c.rank_in_group * 100 + round) as f32];
                c.allreduce_mean(&mut data).unwrap();
                out.push(data[0]);
            }
            out
        });
        // mean over ranks of (rank*100 + round) = 150 + round.
        for r in &results {
            for (round, &x) in r.iter().enumerate() {
                assert!((x - (150.0 + round as f32)).abs() < 1e-4, "round {round}: {x}");
            }
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..3 {
            let results = run_group_ok(3, move |c| {
                let mut data = if c.rank_in_group == root {
                    vec![42.0f32, 7.0]
                } else {
                    vec![0.0, 0.0]
                };
                c.broadcast(root, &mut data).unwrap();
                data
            });
            for r in results {
                assert_eq!(r, vec![42.0, 7.0], "root {root}");
            }
        }
    }

    #[test]
    fn allgather_collects_per_rank_values() {
        let results =
            run_group_ok(4, |c| c.allgather_f64(c.rank_in_group as f64 * 1.5).unwrap());
        for r in results {
            assert_eq!(r, vec![0.0, 1.5, 3.0, 4.5]);
        }
    }

    #[test]
    fn single_member_group_is_identity() {
        let comms = Comm::group(1);
        let mut data = vec![3.0f32, 4.0];
        comms[0].allreduce_mean(&mut data).unwrap();
        assert_eq!(data, vec![3.0, 4.0]);
        comms[0].barrier().unwrap();
    }

    #[test]
    fn stats_count_traffic() {
        let results = run_group_ok(2, |c| {
            let mut d = vec![0f32; 10];
            c.allreduce_mean(&mut d).unwrap();
            c.stats()
        });
        for st in results {
            assert_eq!(st.elems, 10);
            assert_eq!(st.rounds, 1);
            assert_eq!(st.overlapped_elems, 0, "sync traffic must not be tagged overlapped");
        }
    }

    #[test]
    fn overlapped_tag_splits_the_counter_without_changing_bits() {
        // Same contribution through both entry points: identical bits out,
        // but only the tagged call moves the overlapped counter.
        let results = run_group_ok(2, |c| {
            let mut sync = vec![c.rank_in_group as f32 + 0.25; 6];
            let mut ovl = sync.clone();
            c.allreduce_mean(&mut sync).unwrap();
            c.allreduce_mean_overlapped(&mut ovl).unwrap();
            (sync, ovl, c.stats())
        });
        for (sync, ovl, st) in results {
            for (a, b) in sync.iter().zip(ovl.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(st.elems, 12);
            assert_eq!(st.rounds, 2);
            assert_eq!(st.overlapped_elems, 6);
        }
        // Size-1 groups tag consistently with the sync shortcut.
        let comms = Comm::group(1);
        let mut d = vec![1.0f32; 3];
        comms[0].allreduce_mean_overlapped(&mut d).unwrap();
        assert_eq!(comms[0].stats().overlapped_elems, 3);
    }

    #[test]
    fn broadcast_counts_toward_stats() {
        // Regression: the seed moved broadcast payloads but never bumped
        // the traffic counter, undercounting comm volume.
        let results = run_group_ok(3, |c| {
            let mut d = vec![c.rank_in_group as f32; 7];
            c.broadcast(1, &mut d).unwrap();
            c.stats()
        });
        for st in results {
            assert_eq!(st.elems, 7, "broadcast payload must be counted");
            assert_eq!(st.rounds, 1);
        }
        // Size-1 groups count too (degenerate but consistent with reduce).
        let comms = Comm::group(1);
        let mut d = vec![0f32; 5];
        comms[0].broadcast(0, &mut d).unwrap();
        assert_eq!(comms[0].stats().elems, 5);
    }

    #[test]
    fn reduction_is_bit_deterministic_across_arrival_orders() {
        // Rank contributions chosen so f64 summation order changes the
        // result: (1e16 + 1.0) - 1e16 == 0.0 but (1e16 - 1e16) + 1.0 == 1.0.
        // Thread scheduling varies arrival order across rounds; rank-order
        // folding must still produce the identical bit pattern every time.
        let contributions = [1e16f32, 1.0, -1e16, 3.5];
        let results = run_group_ok(4, move |c| {
            let mut out = Vec::new();
            for _ in 0..200 {
                let mut d = vec![contributions[c.rank_in_group]];
                c.allreduce_sum(&mut d).unwrap();
                out.push(d[0].to_bits());
            }
            out
        });
        let expected = results[0][0];
        for r in &results {
            for (round, &bits) in r.iter().enumerate() {
                assert_eq!(
                    bits, expected,
                    "round {round}: nondeterministic reduction ({} vs {})",
                    f32::from_bits(bits),
                    f32::from_bits(expected)
                );
            }
        }
    }

    #[test]
    fn f64_sum_is_exact_and_bit_deterministic() {
        // f64 payloads must survive the exchange without an f32 round-trip
        // (0.1 is not representable in f32) and fold in rank order: the
        // cancellation pattern (1e18 + 1.0) - 1e18 distinguishes fold
        // orders, so 200 rounds under varying thread scheduling must all
        // produce the identical bit pattern.
        let contributions = [1e18f64, 1.0, -1e18, 0.1];
        let results = run_group_ok(4, move |c| {
            let mut out = Vec::new();
            for _ in 0..200 {
                let mut d = vec![contributions[c.rank_in_group], 0.1];
                c.allreduce_sum_f64(&mut d).unwrap();
                out.push((d[0].to_bits(), d[1].to_bits()));
            }
            out
        });
        let expected = results[0][0];
        assert_eq!(f64::from_bits(results[0][0].1), 0.4);
        for r in &results {
            for (round, &bits) in r.iter().enumerate() {
                assert_eq!(bits, expected, "round {round}: nondeterministic f64 fold");
            }
        }
    }

    #[test]
    fn f64_sum_counts_stats_and_is_identity_alone() {
        let results = run_group_ok(2, |c| {
            let mut d = vec![1.5f64; 9];
            c.allreduce_sum_f64(&mut d).unwrap();
            (d, c.stats())
        });
        for (d, st) in results {
            assert!(d.iter().all(|&x| x == 3.0));
            assert_eq!(st.elems, 9);
            assert_eq!(st.rounds, 1);
        }
        let comms = Comm::group(1);
        let mut d = vec![0.3f64, -7.25];
        comms[0].allreduce_sum_f64(&mut d).unwrap();
        assert_eq!(d, vec![0.3, -7.25]);
        assert_eq!(comms[0].stats().elems, 2);
    }

    #[test]
    fn f64_sum_surfaces_rank_failure() {
        let results = run_group_with(3, Duration::from_secs(10), |c| {
            if c.rank_in_group == 2 {
                panic!("injected: rank 2 dies before the f64 collective");
            }
            let mut d = vec![1.0f64; 4];
            c.allreduce_sum_f64(&mut d)
        });
        for r in &results[..2] {
            assert_eq!(
                r.as_ref().unwrap(),
                &Err(CommError::RankFailure { rank: 2 }),
                "peers must see the failed rank, not deadlock"
            );
        }
    }

    #[test]
    fn panicked_member_poisons_the_group() {
        // Rank 0 panics before ever joining the collective; ranks 1 and 2
        // must get a typed RankFailure naming rank 0 — not a hang.
        let results = run_group_with(3, Duration::from_secs(10), |c| {
            if c.rank_in_group == 0 {
                panic!("injected: rank 0 dies before the collective");
            }
            let mut d = vec![1.0f32; 4];
            c.allreduce_mean(&mut d)
        });
        assert_eq!(results[0], Err(CommError::RankFailure { rank: 0 }));
        for r in &results[1..] {
            assert_eq!(
                r.as_ref().unwrap(),
                &Err(CommError::RankFailure { rank: 0 }),
                "peers must see the failed rank, not deadlock"
            );
        }
    }

    #[test]
    fn straggler_past_timeout_yields_typed_timeout() {
        let results = run_group_with(2, Duration::from_millis(50), |c| {
            if c.rank_in_group == 1 {
                // Never calls the collective but exits cleanly (guard
                // disarmed) — a pure straggler from rank 0's viewpoint.
                std::thread::sleep(Duration::from_millis(200));
                return Ok(());
            }
            let mut d = vec![0f32; 2];
            c.allreduce_sum(&mut d)
        });
        assert_eq!(
            results[0].as_ref().unwrap(),
            &Err(CommError::Timeout { waited_ms: 50 })
        );
        assert!(results[1].as_ref().unwrap().is_ok());
    }

    #[test]
    fn explicit_labels_name_global_ranks_in_failures() {
        // A head group labeled with global ranks [4, 5]: member 1's death
        // must be reported as global rank 5.
        let comms = Comm::group_with(2, Duration::from_secs(5), Some(vec![4, 5]));
        assert_eq!(comms[0].label(), 4);
        assert_eq!(comms[1].label(), 5);
        comms[1].poison();
        let mut d = vec![0f32; 1];
        assert_eq!(
            comms[0].allreduce_sum(&mut d),
            Err(CommError::RankFailure { rank: 5 })
        );
    }

    #[test]
    fn disarmed_guard_does_not_poison() {
        let comms = Comm::group(2);
        let g = comms[0].member_guard();
        g.disarm();
        // Group still healthy: a 2-rank reduce completes.
        let results: Vec<_> = std::thread::scope(|s| {
            comms
                .into_iter()
                .map(|c| {
                    s.spawn(move || {
                        let mut d = vec![2.0f32];
                        c.allreduce_mean(&mut d).map(|()| d[0])
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for r in results {
            assert_eq!(r.unwrap(), 2.0);
        }
    }
}
