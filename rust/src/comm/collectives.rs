//! Shared-memory collective operations over a group of rank threads.
//!
//! The trainer's "processes" are OS threads (one per simulated GPU); a
//! `Comm` is one member's handle to a process group, with NCCL-style
//! collectives implemented as a sense-gated rendezvous: ranks accumulate
//! into a shared buffer, the last arrival finalizes, everyone copies out,
//! and the round drains before the next may begin. Numerically this is
//! exactly the averaging a ring allreduce performs; the *cost* of the ring
//! on a real fabric is priced separately by `scalesim` (same code path, a
//! virtual clock instead of a wall clock).
//!
//! Traffic counters record every payload so tests and the scaling study can
//! verify the paper's key claim: multi-task parallelism replaces one global
//! `P_s + N_h*P_h` allreduce with a global `P_s` allreduce plus per-head
//! local `P_h` allreduces.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

#[derive(Default)]
struct RoundState {
    /// Per-rank contributions of the in-flight round (rank-indexed). The
    /// last arrival folds them together in RANK order, which makes the
    /// reduction a pure function of the inputs — independent of thread
    /// arrival order. (The seed accumulated in arrival order, so multi-rank
    /// runs were reproducible only to ~1e-5; checkpoint resume needs
    /// bit-identity across whole reruns.)
    parts: Vec<Vec<f64>>,
    /// Finalized round result every member copies out.
    accum: Vec<f64>,
    arrived: usize,
    departing: usize,
}

struct Shared {
    size: usize,
    state: Mutex<RoundState>,
    cv: Condvar,
    /// Total f32 elements moved through collectives (allreduce AND
    /// broadcast) on this communicator. Broadcast was not counted by the
    /// seed, which undercounted the traffic behind the paper's P_s-vs-P_h
    /// communication-volume claim once checkpoint restores entered the mix.
    reduced_elems: AtomicU64,
    /// Number of collective rounds completed.
    rounds: AtomicU64,
}

/// One member's handle to a process group.
#[derive(Clone)]
pub struct Comm {
    shared: Arc<Shared>,
    pub rank_in_group: usize,
}

impl Comm {
    /// Create a group of `n` communicator handles (one per member thread).
    pub fn group(n: usize) -> Vec<Comm> {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            size: n,
            state: Mutex::new(RoundState {
                parts: vec![Vec::new(); n],
                ..RoundState::default()
            }),
            cv: Condvar::new(),
            reduced_elems: AtomicU64::new(0),
            rounds: AtomicU64::new(0),
        });
        (0..n).map(|i| Comm { shared: Arc::clone(&shared), rank_in_group: i }).collect()
    }

    pub fn size(&self) -> usize {
        self.shared.size
    }

    /// Elementwise mean across the group, in place. All members must call.
    pub fn allreduce_mean(&self, data: &mut [f32]) {
        self.reduce(data, true);
    }

    /// Elementwise sum across the group, in place.
    pub fn allreduce_sum(&self, data: &mut [f32]) {
        self.reduce(data, false);
    }

    fn reduce(&self, data: &mut [f32], mean: bool) {
        let sh = &self.shared;
        if sh.size == 1 {
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            return;
        }
        let mut st = sh.state.lock().unwrap();
        // Gate: previous round must fully drain first.
        while st.departing > 0 {
            st = sh.cv.wait(st).unwrap();
        }
        // Deposit this rank's contribution (widened to f64, which keeps DDP
        // means stable) in its own slot; the final sum happens in rank
        // order so the result is arrival-order independent.
        {
            let slot = &mut st.parts[self.rank_in_group];
            slot.clear();
            slot.extend(data.iter().map(|&x| x as f64));
        }
        st.arrived += 1;
        if st.arrived == sh.size {
            {
                let RoundState { parts, accum, .. } = &mut *st;
                accum.clear();
                accum.resize(data.len(), 0.0);
                for part in parts.iter() {
                    for (a, &x) in accum.iter_mut().zip(part.iter()) {
                        *a += x;
                    }
                }
                if mean {
                    let inv = 1.0 / sh.size as f64;
                    for a in accum.iter_mut() {
                        *a *= inv;
                    }
                }
            }
            st.arrived = 0;
            st.departing = sh.size;
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            sh.cv.notify_all();
        } else {
            while st.departing == 0 {
                st = sh.cv.wait(st).unwrap();
            }
        }
        for (x, &a) in data.iter_mut().zip(st.accum.iter()) {
            *x = a as f32;
        }
        st.departing -= 1;
        if st.departing == 0 {
            sh.cv.notify_all();
        }
    }

    /// Broadcast `data` from `root` to every member, in place. The payload
    /// counts toward [`Comm::stats`] like any other collective (the seed
    /// moved the bytes but never incremented the traffic counter, so
    /// broadcast-heavy paths — checkpoint restore in particular — were
    /// invisible to the communication-volume accounting).
    pub fn broadcast(&self, root: usize, data: &mut [f32]) {
        let sh = &self.shared;
        if sh.size == 1 {
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            return;
        }
        let mut st = sh.state.lock().unwrap();
        while st.departing > 0 {
            st = sh.cv.wait(st).unwrap();
        }
        if self.rank_in_group == root {
            st.accum.clear();
            st.accum.extend(data.iter().map(|&x| x as f64));
        }
        st.arrived += 1;
        if st.arrived == sh.size {
            st.arrived = 0;
            st.departing = sh.size;
            sh.rounds.fetch_add(1, Ordering::Relaxed);
            sh.reduced_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
            sh.cv.notify_all();
        } else {
            while st.departing == 0 {
                st = sh.cv.wait(st).unwrap();
            }
        }
        // Root may have arrived last; accum is valid in either case because
        // only the root writes it and every writer-arrival precedes release.
        for (x, &a) in data.iter_mut().zip(st.accum.iter()) {
            *x = a as f32;
        }
        st.departing -= 1;
        if st.departing == 0 {
            sh.cv.notify_all();
        }
    }

    /// Barrier across the group.
    pub fn barrier(&self) {
        let mut unit = [0f32; 0];
        self.reduce(&mut unit, false);
    }

    /// Allgather of one f64 per rank (metrics aggregation).
    pub fn allgather_f64(&self, value: f64) -> Vec<f64> {
        let n = self.shared.size;
        let mut slots = vec![0f32; 2 * n];
        // Encode f64 as two f32 halves to reuse the f32 reduce path without
        // precision loss on metric magnitudes: hi = f32(value), lo = f32(value - hi).
        let hi = value as f32;
        let lo = (value - hi as f64) as f32;
        slots[2 * self.rank_in_group] = hi;
        slots[2 * self.rank_in_group + 1] = lo;
        self.allreduce_sum(&mut slots);
        (0..n).map(|i| slots[2 * i] as f64 + slots[2 * i + 1] as f64).collect()
    }

    /// (total f32 elements moved through collectives, completed rounds).
    pub fn stats(&self) -> (u64, u64) {
        (
            self.shared.reduced_elems.load(Ordering::Relaxed),
            self.shared.rounds.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_group<T: Send + 'static>(
        n: usize,
        f: impl Fn(Comm) -> T + Send + Sync + Clone + 'static,
    ) -> Vec<T> {
        let comms = Comm::group(n);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_mean_averages() {
        let results = run_group(4, |c| {
            let mut data = vec![c.rank_in_group as f32; 8];
            c.allreduce_mean(&mut data);
            data
        });
        for r in results {
            for x in r {
                assert!((x - 1.5).abs() < 1e-6); // mean of 0,1,2,3
            }
        }
    }

    #[test]
    fn allreduce_sum_sums() {
        let results = run_group(3, |c| {
            let mut data = vec![1.0f32, 2.0];
            c.allreduce_sum(&mut data);
            data
        });
        for r in results {
            assert_eq!(r, vec![3.0, 6.0]);
        }
    }

    #[test]
    fn repeated_rounds_do_not_interleave() {
        let results = run_group(4, |c| {
            let mut out = Vec::new();
            for round in 0..50 {
                let mut data = vec![(c.rank_in_group * 100 + round) as f32];
                c.allreduce_mean(&mut data);
                out.push(data[0]);
            }
            out
        });
        // mean over ranks of (rank*100 + round) = 150 + round.
        for r in &results {
            for (round, &x) in r.iter().enumerate() {
                assert!((x - (150.0 + round as f32)).abs() < 1e-4, "round {round}: {x}");
            }
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..3 {
            let results = run_group(3, move |c| {
                let mut data = if c.rank_in_group == root {
                    vec![42.0f32, 7.0]
                } else {
                    vec![0.0, 0.0]
                };
                c.broadcast(root, &mut data);
                data
            });
            for r in results {
                assert_eq!(r, vec![42.0, 7.0], "root {root}");
            }
        }
    }

    #[test]
    fn allgather_collects_per_rank_values() {
        let results = run_group(4, |c| c.allgather_f64(c.rank_in_group as f64 * 1.5));
        for r in results {
            assert_eq!(r, vec![0.0, 1.5, 3.0, 4.5]);
        }
    }

    #[test]
    fn single_member_group_is_identity() {
        let comms = Comm::group(1);
        let mut data = vec![3.0f32, 4.0];
        comms[0].allreduce_mean(&mut data);
        assert_eq!(data, vec![3.0, 4.0]);
        comms[0].barrier();
    }

    #[test]
    fn stats_count_traffic() {
        let results = run_group(2, |c| {
            let mut d = vec![0f32; 10];
            c.allreduce_mean(&mut d);
            c.stats()
        });
        for (elems, rounds) in results {
            assert_eq!(elems, 10);
            assert_eq!(rounds, 1);
        }
    }

    #[test]
    fn broadcast_counts_toward_stats() {
        // Regression: the seed moved broadcast payloads but never bumped
        // the traffic counter, undercounting comm volume.
        let results = run_group(3, |c| {
            let mut d = vec![c.rank_in_group as f32; 7];
            c.broadcast(1, &mut d);
            c.stats()
        });
        for (elems, rounds) in results {
            assert_eq!(elems, 7, "broadcast payload must be counted");
            assert_eq!(rounds, 1);
        }
        // Size-1 groups count too (degenerate but consistent with reduce).
        let comms = Comm::group(1);
        let mut d = vec![0f32; 5];
        comms[0].broadcast(0, &mut d);
        assert_eq!(comms[0].stats().0, 5);
    }

    #[test]
    fn reduction_is_bit_deterministic_across_arrival_orders() {
        // Rank contributions chosen so f64 summation order changes the
        // result: (1e16 + 1.0) - 1e16 == 0.0 but (1e16 - 1e16) + 1.0 == 1.0.
        // Thread scheduling varies arrival order across rounds; rank-order
        // folding must still produce the identical bit pattern every time.
        let contributions = [1e16f32, 1.0, -1e16, 3.5];
        let results = run_group(4, move |c| {
            let mut out = Vec::new();
            for _ in 0..200 {
                let mut d = vec![contributions[c.rank_in_group]];
                c.allreduce_sum(&mut d);
                out.push(d[0].to_bits());
            }
            out
        });
        let expected = results[0][0];
        for r in &results {
            for (round, &bits) in r.iter().enumerate() {
                assert_eq!(
                    bits, expected,
                    "round {round}: nondeterministic reduction ({} vs {})",
                    f32::from_bits(bits),
                    f32::from_bits(expected)
                );
            }
        }
    }
}
