//! Compute/communication overlap: bucketed gradient reduction on a
//! per-rank comm thread while the analytic backward pass still runs.
//!
//! The synchronous trainer blocks every rank on one monolithic
//! `allreduce_mean` over the whole flattened gradient after backward
//! completes. This module splits that payload into size-bounded **buckets**
//! ordered by backward completion — `branch.*` leaves finish first, then
//! `encoder.layers.{li}.*` in reverse layer order, `encoder.embed` last —
//! and reduces each bucket on a dedicated comm thread as soon as its last
//! block is signaled by `model::egnn::backward_observed`, so the reduction
//! of early buckets overlaps the backward compute of later ones.
//!
//! **Determinism argument.** The shared-memory reduction is elementwise:
//! each element's reduced value is a pure function of the group's
//! contributions for that element (f64 widening, rank-order fold, one
//! multiply by `1/size`). Splitting the payload into buckets therefore
//! changes *when* each element is reduced, never *what* it reduces to — the
//! overlapped path is BIT-identical to the monolithic call, which keeps
//! checkpoint kill-at-k resume parity intact (`integration_overlap.rs`
//! asserts both). Submission order is a pure function of the bucket plan
//! (identical on every rank), so no two ranks ever disagree on the round
//! sequence of a communicator.
//!
//! **Failure behavior.** The comm thread issues ordinary collectives, so a
//! dead peer surfaces as the usual typed [`CommError::RankFailure`] on the
//! next bucket; [`OverlapReducer`]'s `Drop` poisons its communicators
//! before joining whenever jobs are still in flight, so a rank aborting
//! mid-step (skip-budget exhaustion, injected fault) wakes the thread out
//! of any blocked rendezvous instead of deadlocking it.

use std::sync::mpsc;

use crate::comm::collectives::{Comm, CommError};
use crate::model::egnn::GradBlock;
use crate::model::params::{LeafMeta, ParamSet};

/// Which communicator a bucket reduces over. Under MTL-par the encoder
/// segment reduces on the global group and the branch segment on the head
/// sub-group; DDP routes both to the global group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Segment {
    Encoder,
    Branch,
}

/// One leaf's placement inside its segment's flat buffer (the order
/// `ParamSet::flatten_prefix_into` writes).
#[derive(Debug, Clone)]
struct BucketLeaf {
    name: String,
    /// Offset into the segment's flat buffer.
    seg_off: usize,
    len: usize,
}

/// A size-bounded group of consecutive (in completion order) leaves that
/// reduces as one collective payload.
#[derive(Debug, Clone)]
pub struct Bucket {
    leaves: Vec<BucketLeaf>,
    /// Total f32 elements in the bucket.
    pub elems: usize,
    /// The bucket is ready once the block with this completion ordinal has
    /// been signaled (the max ordinal over its leaves).
    pub ready_ordinal: usize,
}

/// Partition of the manifest's parameter leaves into gradient buckets
/// ordered by backward completion. Branch buckets are contiguous ranges of
/// the branch flat buffer (all branch leaves share ordinal 0); encoder
/// buckets follow completion order (layer `L-1` first, `embed` last), which
/// is NOT the flat order — each bucket records per-leaf offsets so reduced
/// values scatter back exactly where `unflatten_prefix_from` expects them.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    enc_buckets: Vec<Bucket>,
    br_buckets: Vec<Bucket>,
    enc_len: usize,
    br_len: usize,
    num_layers: usize,
}

impl BucketPlan {
    /// Build a plan over `metas` (the manifest's full parameter leaf list,
    /// `branch.*` then `encoder.*`). `bucket_elems` bounds each bucket's
    /// payload; a single leaf larger than the bound gets its own bucket.
    pub fn new(
        metas: &[LeafMeta],
        num_layers: usize,
        bucket_elems: usize,
    ) -> anyhow::Result<BucketPlan> {
        anyhow::ensure!(bucket_elems >= 1, "bucket_elems must be >= 1");
        let mut enc_leaves: Vec<(usize, BucketLeaf)> = Vec::new();
        let mut br_leaves: Vec<(usize, BucketLeaf)> = Vec::new();
        let (mut enc_len, mut br_len) = (0usize, 0usize);
        for m in metas {
            let len = m.numel();
            if m.name.starts_with("branch.") {
                let leaf = BucketLeaf { name: m.name.clone(), seg_off: br_len, len };
                br_leaves.push((GradBlock::Branch.ordinal(num_layers), leaf));
                br_len += len;
            } else if m.name.starts_with("encoder.") {
                let block = block_of_encoder_leaf(&m.name, num_layers)?;
                let leaf = BucketLeaf { name: m.name.clone(), seg_off: enc_len, len };
                enc_leaves.push((block.ordinal(num_layers), leaf));
                enc_len += len;
            } else {
                anyhow::bail!("leaf '{}' is neither branch.* nor encoder.*", m.name);
            }
        }
        // Completion order; the stable sort keeps flat order within a block.
        enc_leaves.sort_by_key(|(ord, _)| *ord);
        Ok(BucketPlan {
            enc_buckets: partition(enc_leaves, bucket_elems),
            br_buckets: partition(br_leaves, bucket_elems),
            enc_len,
            br_len,
            num_layers,
        })
    }

    pub fn num_layers(&self) -> usize {
        self.num_layers
    }

    /// Flat length of the encoder segment.
    pub fn enc_len(&self) -> usize {
        self.enc_len
    }

    /// Flat length of the branch segment.
    pub fn br_len(&self) -> usize {
        self.br_len
    }

    pub fn enc_buckets(&self) -> &[Bucket] {
        &self.enc_buckets
    }

    pub fn br_buckets(&self) -> &[Bucket] {
        &self.br_buckets
    }

    fn buckets(&self, seg: Segment) -> &[Bucket] {
        match seg {
            Segment::Encoder => &self.enc_buckets,
            Segment::Branch => &self.br_buckets,
        }
    }
}

/// Map an `encoder.*` leaf name to its backward block.
fn block_of_encoder_leaf(name: &str, num_layers: usize) -> anyhow::Result<GradBlock> {
    if name == "encoder.embed" {
        return Ok(GradBlock::Embed);
    }
    if let Some(rest) = name.strip_prefix("encoder.layers.") {
        let li: usize = rest
            .split('.')
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|_| anyhow::anyhow!("cannot parse layer index from leaf '{name}'"))?;
        anyhow::ensure!(li < num_layers, "leaf '{name}' exceeds num_layers={num_layers}");
        return Ok(GradBlock::Layer(li));
    }
    anyhow::bail!("unrecognized encoder leaf '{name}'")
}

/// Greedy size-bounded partition of completion-ordered leaves.
fn partition(leaves: Vec<(usize, BucketLeaf)>, bucket_elems: usize) -> Vec<Bucket> {
    let mut out: Vec<Bucket> = Vec::new();
    for (ord, leaf) in leaves {
        let open = match out.last() {
            Some(b) => b.elems + leaf.len <= bucket_elems && !b.leaves.is_empty(),
            None => false,
        };
        if open {
            let b = out.last_mut().expect("checked non-empty above");
            b.elems += leaf.len;
            b.ready_ordinal = b.ready_ordinal.max(ord);
            b.leaves.push(leaf);
        } else {
            out.push(Bucket { elems: leaf.len, ready_ordinal: ord, leaves: vec![leaf] });
        }
    }
    out
}

struct Job {
    seq: u64,
    seg: Segment,
    dest: usize,
    offset: usize,
    buf: Vec<f32>,
}

struct Done {
    job: Job,
    res: Result<(), CommError>,
}

/// A reduced bucket handed back by [`OverlapReducer::finish`]: scatter
/// `data` into the destination tagged at submission (`seg`/`dest`/`offset`
/// are echoed verbatim), then return the buffer via
/// [`OverlapReducer::recycle`].
pub struct ReducedBucket {
    pub seg: Segment,
    pub dest: usize,
    pub offset: usize,
    pub data: Vec<f32>,
}

/// How many bucket reductions may be in flight on the comm thread at once
/// (double-buffered: one reducing while the next is staged).
const IN_FLIGHT_CAP: usize = 2;

/// Per-rank asynchronous bucket reducer: one comm thread executing
/// `allreduce_mean_overlapped` calls in submission order against clones of
/// the rank's communicators, with a recycled double-buffered payload pool.
pub struct OverlapReducer {
    job_tx: Option<mpsc::Sender<Job>>,
    done_rx: mpsc::Receiver<Done>,
    handle: Option<std::thread::JoinHandle<()>>,
    enc_comm: Comm,
    br_comm: Comm,
    pool: Vec<Vec<f32>>,
    completed: Vec<Done>,
    in_flight: usize,
    seq: u64,
}

impl OverlapReducer {
    /// Spawn the comm thread. `enc_comm` serves [`Segment::Encoder`]
    /// buckets and `br_comm` serves [`Segment::Branch`] buckets (pass two
    /// clones of the same communicator for pure data parallelism).
    pub fn new(enc_comm: Comm, br_comm: Comm) -> OverlapReducer {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let (done_tx, done_rx) = mpsc::channel::<Done>();
        let (enc, br) = (enc_comm.clone(), br_comm.clone());
        let handle = std::thread::spawn(move || {
            while let Ok(mut job) = job_rx.recv() {
                let res = match job.seg {
                    Segment::Encoder => enc.allreduce_mean_overlapped(&mut job.buf),
                    Segment::Branch => br.allreduce_mean_overlapped(&mut job.buf),
                };
                // A failed collective still reports home; later jobs fail
                // fast on the poisoned group rather than deadlocking.
                if done_tx.send(Done { job, res }).is_err() {
                    return;
                }
            }
        });
        OverlapReducer {
            job_tx: Some(job_tx),
            done_rx,
            handle: Some(handle),
            enc_comm,
            br_comm,
            pool: Vec::new(),
            completed: Vec::new(),
            in_flight: 0,
            seq: 0,
        }
    }

    /// Enqueue one bucket reduction. Blocks only when both in-flight slots
    /// are busy (backward has outrun the fabric), in which case it waits
    /// for the oldest bucket to complete first.
    pub fn submit(
        &mut self,
        seg: Segment,
        dest: usize,
        offset: usize,
        data: &[f32],
    ) -> anyhow::Result<()> {
        while self.in_flight >= IN_FLIGHT_CAP {
            let done = self.recv_done()?;
            self.completed.push(done);
        }
        let mut buf = self.pool.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(data);
        let job = Job { seq: self.seq, seg, dest, offset, buf };
        self.seq += 1;
        self.job_tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("overlap reducer already shut down"))?
            .send(job)
            .map_err(|_| anyhow::anyhow!("overlap comm thread exited unexpectedly"))?;
        self.in_flight += 1;
        Ok(())
    }

    /// Split `data` into `bucket_elems`-bounded contiguous chunks and
    /// submit each (offset = chunk start). Every rank must call with the
    /// same lengths so the chunk sequence — and thus the collective round
    /// order — is identical group-wide.
    pub fn submit_chunks(
        &mut self,
        seg: Segment,
        dest: usize,
        data: &[f32],
        bucket_elems: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(bucket_elems >= 1, "bucket_elems must be >= 1");
        let mut off = 0;
        while off < data.len() {
            let end = (off + bucket_elems).min(data.len());
            self.submit(seg, dest, off, &data[off..end])?;
            off = end;
        }
        Ok(())
    }

    fn recv_done(&mut self) -> anyhow::Result<Done> {
        let done = self
            .done_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("overlap comm thread exited unexpectedly"))?;
        self.in_flight -= 1;
        Ok(done)
    }

    /// Drain every in-flight job and hand back the reduced buckets in
    /// submission order. The first collective failure (by submission
    /// sequence) is returned as the typed comm error so callers abort
    /// exactly like a failed synchronous `allreduce_mean`.
    pub fn finish(&mut self) -> anyhow::Result<Vec<ReducedBucket>> {
        while self.in_flight > 0 {
            let done = self.recv_done()?;
            self.completed.push(done);
        }
        let mut done = std::mem::take(&mut self.completed);
        done.sort_by_key(|d| d.job.seq);
        let first_err: Option<CommError> = done.iter().find_map(|d| d.res.err());
        if let Some(err) = first_err {
            // Recycle what we can; the error aborts the step either way.
            for d in done {
                self.pool.push(d.job.buf);
            }
            return Err(err.into());
        }
        Ok(done
            .into_iter()
            .map(|d| ReducedBucket {
                seg: d.job.seg,
                dest: d.job.dest,
                offset: d.job.offset,
                data: d.job.buf,
            })
            .collect())
    }

    /// Return a consumed bucket's buffer to the pool.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        self.pool.push(buf);
    }
}

impl Drop for OverlapReducer {
    fn drop(&mut self) {
        // Dropped with work in flight means the owning rank is aborting
        // mid-step: poison the groups FIRST so the comm thread (and every
        // peer) wakes out of any blocked rendezvous with a typed failure,
        // then close the channel and join.
        if self.in_flight > 0 {
            self.enc_comm.poison();
            self.br_comm.poison();
        }
        self.job_tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Streaming gradient sink for the per-step backward: implements
/// `runtime::backend::GradObserver`, submitting each bucket the moment its
/// last block is signaled. One sink lives for a whole rank loop; call
/// [`OverlapSink::begin_step`] before the step and
/// [`OverlapSink::finish_step`] after to collect the reduced segments.
pub struct OverlapSink {
    plan: BucketPlan,
    reducer: OverlapReducer,
    gather: Vec<f32>,
    /// Submit all-zero payloads (non-finite loss, injected fault): the rank
    /// still joins every collective so peers never desynchronize.
    zero: bool,
    enc_cursor: usize,
    br_cursor: usize,
}

impl OverlapSink {
    pub fn new(plan: BucketPlan, enc_comm: Comm, br_comm: Comm) -> OverlapSink {
        OverlapSink {
            plan,
            reducer: OverlapReducer::new(enc_comm, br_comm),
            gather: Vec::new(),
            zero: false,
            enc_cursor: 0,
            br_cursor: 0,
        }
    }

    pub fn plan(&self) -> &BucketPlan {
        &self.plan
    }

    /// Arm the sink for one training step. `force_zero` pre-declares the
    /// step skipped (fault injection): every bucket carries zeros,
    /// replicating the synchronous skip-batch path bit-for-bit.
    pub fn begin_step(&mut self, force_zero: bool) {
        self.zero = force_zero;
        self.enc_cursor = 0;
        self.br_cursor = 0;
    }

    /// Whether this step's payloads were zeroed (observed or forced
    /// non-finite loss).
    pub fn zeroed(&self) -> bool {
        self.zero
    }

    /// Record the step's loss before any block is submitted: a non-finite
    /// loss switches every bucket to zeros (the synchronous path zeroes the
    /// flat gradient before its allreduce; same values, same rounds).
    pub fn observe_loss(&mut self, loss: f64) {
        if !loss.is_finite() {
            self.zero = true;
        }
    }

    /// Signal that `block`'s leaves are final in `grads`; submits every
    /// bucket whose readiness ordinal is now reached. Branch buckets are
    /// always drained before encoder buckets at the same ordinal — a fixed
    /// interleaving rule so all ranks submit in the same order.
    pub fn observe_block(&mut self, block: GradBlock, grads: &ParamSet) -> anyhow::Result<()> {
        let ord = block.ordinal(self.plan.num_layers);
        while self.br_cursor < self.plan.br_buckets.len()
            && self.plan.br_buckets[self.br_cursor].ready_ordinal <= ord
        {
            self.submit_bucket(Segment::Branch, self.br_cursor, grads)?;
            self.br_cursor += 1;
        }
        while self.enc_cursor < self.plan.enc_buckets.len()
            && self.plan.enc_buckets[self.enc_cursor].ready_ordinal <= ord
        {
            self.submit_bucket(Segment::Encoder, self.enc_cursor, grads)?;
            self.enc_cursor += 1;
        }
        Ok(())
    }

    fn submit_bucket(
        &mut self,
        seg: Segment,
        idx: usize,
        grads: &ParamSet,
    ) -> anyhow::Result<()> {
        let bucket = &self.plan.buckets(seg)[idx];
        self.gather.clear();
        if self.zero {
            self.gather.resize(bucket.elems, 0.0);
        } else {
            for leaf in &bucket.leaves {
                let t = grads
                    .get(&leaf.name)
                    .ok_or_else(|| anyhow::anyhow!("gradient leaf '{}' missing", leaf.name))?;
                self.gather.extend_from_slice(t.as_f32());
            }
            anyhow::ensure!(
                self.gather.len() == bucket.elems,
                "bucket gather size mismatch ({} vs {})",
                self.gather.len(),
                bucket.elems
            );
        }
        // Temporarily take the scratch to appease the borrow between the
        // gather buffer and the reducer; put it back after the copy-in.
        let gather = std::mem::take(&mut self.gather);
        let res = self.reducer.submit(seg, idx, 0, &gather);
        self.gather = gather;
        res
    }

    /// Drain the comm thread and scatter every reduced bucket into the two
    /// segment buffers (resized to the full segment lengths), exactly as
    /// the synchronous path leaves them after its monolithic allreduce.
    pub fn finish_step(
        &mut self,
        enc_flat: &mut Vec<f32>,
        br_flat: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.br_cursor == self.plan.br_buckets.len()
                && self.enc_cursor == self.plan.enc_buckets.len(),
            "backward did not signal every gradient block \
             ({}/{} branch, {}/{} encoder buckets submitted)",
            self.br_cursor,
            self.plan.br_buckets.len(),
            self.enc_cursor,
            self.plan.enc_buckets.len()
        );
        enc_flat.clear();
        enc_flat.resize(self.plan.enc_len, 0.0);
        br_flat.clear();
        br_flat.resize(self.plan.br_len, 0.0);
        for rb in self.reducer.finish()? {
            let (bucket, seg_flat) = match rb.seg {
                Segment::Encoder => (&self.plan.enc_buckets[rb.dest], &mut *enc_flat),
                Segment::Branch => (&self.plan.br_buckets[rb.dest], &mut *br_flat),
            };
            let mut off = 0;
            for leaf in &bucket.leaves {
                seg_flat[leaf.seg_off..leaf.seg_off + leaf.len]
                    .copy_from_slice(&rb.data[off..off + leaf.len]);
                off += leaf.len;
            }
            self.reducer.recycle(rb.data);
        }
        Ok(())
    }
}

/// The sink IS a [`crate::runtime::backend::GradObserver`]: hand it to
/// `Engine::train_step_observed_unchecked` and buckets stream out of the
/// backward as their blocks complete.
impl crate::runtime::backend::GradObserver for OverlapSink {
    fn loss_ready(&mut self, loss: f64) {
        self.observe_loss(loss);
    }

    fn block_ready(&mut self, block: GradBlock, grads: &ParamSet) -> anyhow::Result<()> {
        self.observe_block(block, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collectives::run_group;

    fn leaf(name: &str, n: usize) -> LeafMeta {
        LeafMeta {
            name: name.into(),
            shape: vec![n],
            dtype: crate::tensor::DType::F32,
            init: None,
        }
    }

    fn metas_2layer() -> Vec<LeafMeta> {
        vec![
            leaf("branch.trunk.w1", 6),
            leaf("branch.energy.w", 3),
            leaf("encoder.embed", 5),
            leaf("encoder.layers.0.edge.w1", 4),
            leaf("encoder.layers.1.edge.w1", 4),
        ]
    }

    #[test]
    fn plan_orders_buckets_by_backward_completion() {
        let plan = BucketPlan::new(&metas_2layer(), 2, 4).unwrap();
        assert_eq!(plan.br_len(), 9);
        assert_eq!(plan.enc_len(), 13);
        // Branch: 6 then 3 (6+3 > 4 → two buckets), all ordinal 0.
        assert_eq!(plan.br_buckets().len(), 3);
        assert!(plan.br_buckets().iter().all(|b| b.ready_ordinal == 0));
        // Encoder completion order: layer 1 (ordinal 1), layer 0 (2),
        // embed (3) — embed is FIRST in flat order but LAST to be ready.
        let ords: Vec<usize> = plan.enc_buckets().iter().map(|b| b.ready_ordinal).collect();
        let mut sorted = ords.clone();
        sorted.sort_unstable();
        assert_eq!(ords, sorted, "encoder buckets must be completion-ordered");
        assert_eq!(*ords.last().unwrap(), 3, "embed bucket readies last");
    }

    #[test]
    fn bucketed_reduction_matches_monolithic_bits() {
        // The reducer over arbitrary chunk boundaries must be bit-identical
        // to one monolithic allreduce_mean of the same payload.
        for &ranks in &[1usize, 2, 8] {
            for &chunk in &[1usize, 3, 7, 64] {
                let results = run_group(ranks, move |c| {
                    let mut mono: Vec<f32> = (0..23)
                        .map(|i| ((i * 31 + c.rank_in_group * 7) as f32).sin() * 1e3)
                        .collect();
                    let src = mono.clone();
                    c.allreduce_mean(&mut mono).unwrap();

                    let mut red = OverlapReducer::new(c.clone(), c.clone());
                    red.submit_chunks(Segment::Encoder, 0, &src, chunk).unwrap();
                    let mut out = vec![0f32; src.len()];
                    for rb in red.finish().unwrap() {
                        out[rb.offset..rb.offset + rb.data.len()].copy_from_slice(&rb.data);
                    }
                    (mono, out)
                });
                for r in results {
                    let (mono, out) = r.unwrap();
                    for (a, b) in mono.iter().zip(out.iter()) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "ranks={ranks} chunk={chunk}: bucketed != monolithic"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dropped_reducer_mid_flight_poisons_instead_of_deadlocking() {
        // Rank 1 submits one bucket then drops its reducer while the job is
        // still formally in flight (an abort mid-step). Rank 0 attempts two
        // collectives: whatever the interleaving, at least one must surface
        // a typed failure promptly — never a hang — because the dropping
        // reducer poisons its groups before joining.
        let results = run_group(2, |c| {
            if c.rank_in_group == 1 {
                let mut red = OverlapReducer::new(c.clone(), c.clone());
                red.submit(Segment::Encoder, 0, 0, &[1.0, 2.0]).unwrap();
                drop(red); // in flight → poisons the group
                return Ok(());
            }
            let mut d = vec![0f32; 2];
            c.allreduce_mean_overlapped(&mut d)?;
            let mut d2 = vec![0f32; 2];
            c.allreduce_mean_overlapped(&mut d2)
        });
        assert!(
            results[0].as_ref().unwrap().is_err(),
            "peer must observe the failure, not deadlock"
        );
        assert!(results[1].as_ref().unwrap().is_ok());
    }
}
