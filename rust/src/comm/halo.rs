//! Halo-exchange plan for graph-parallel (domain-decomposed) training.
//!
//! One huge structure is partitioned across ranks by atom: the
//! [`crate::data::featurized::FeaturizedStore`] assigns every atom a
//! segment 0..8 (contiguous chunks of the cell-sorted atom order), and rank
//! `r` of a world `W in {1,2,4,8}` owns segments `r*8/W..(r+1)*8/W`. A rank
//! computes EGNN layer work only for its owned atoms (node work) and for
//! edges whose destination it owns (edge work), which is where the O(n*h^2)
//! MLP cost lives. The graph topology itself is replicated — atomistic
//! graphs are edge lists, not dense tensors, so replicating connectivity is
//! cheap while the feature/activation math is what must be divided.
//!
//! Cross-owner edges need remote data in two places:
//!
//! * **forward**: the edge MLP of an edge owned by `owner(dst)` reads the
//!   hidden state `h[src]` of a possibly remote atom. The *boundary atoms*
//!   (atoms appearing as `src` of any cross-owner edge) are exchanged
//!   before every EGNN block.
//! * **backward**: the analytic backward of the same edge produces a
//!   gradient contribution `d_x[ei][:h]` for `h[src]`, computed by
//!   `owner(dst)` but folded by `owner(src)`. The *boundary edges* (the
//!   cross-owner edges themselves) are exchanged once per block in reverse.
//!
//! Both exchanges ride the same slotted [`Comm::allreduce_sum_f64`]: the
//! plan lays boundary slots out in a canonical order — atoms by
//! `(owner_rank, global_atom_index)`, edges by global edge index — the slot
//! owner deposits the value, everyone else deposits `0.0`, and the rank-
//! ordered f64 fold returns the owner's exact bits to every rank
//! (`0.0 + x == x`). The exchange is therefore bit-deterministic and
//! world-shape independent, which the trainer's N-rank == single-rank
//! parity guarantee rests on.
//!
//! The per-atom vector feature `v` never crosses ranks: it is accumulated
//! and consumed strictly per destination atom, so only `h` is exchanged
//! (the halo payload the ISSUE's `h`/`v` phrasing bounds from above).

use crate::comm::collectives::{Comm, CommError};
use crate::data::graph::Edge;

/// Number of ownership segments every structure is split into. Fixed at 8
/// (the largest supported world) so the segment partition — and therefore
/// every per-segment fold order — is independent of the world size.
pub const SEGMENTS: usize = 8;

/// Slots of the per-step loss allreduce: per-segment partial sums of the
/// energy prediction, the squared force error and the absolute force error
/// (see `model::graphpar`).
pub const LOSS_SLOTS: usize = 3 * SEGMENTS;

/// Owning rank of a segment: rank `r` owns segments `r*8/W..(r+1)*8/W`.
#[inline]
pub fn segment_owner(segment: u8, world: usize) -> usize {
    debug_assert!(matches!(world, 1 | 2 | 4 | 8), "graph-par world must divide 8");
    segment as usize * world / SEGMENTS
}

/// Send/recv lists of one structure's domain decomposition, built once per
/// structure and reused every step (the layout is a pure function of the
/// segment assignment, the edge list and the world size).
pub struct HaloPlan {
    world: usize,
    /// Owning rank per atom.
    owners: Vec<usize>,
    /// Boundary atoms (appear as `src` of a cross-owner edge), sorted by
    /// `(owner_rank, global_atom_index)` — the canonical slot order.
    boundary_atoms: Vec<u32>,
    /// Cross-owner edges, ascending global edge index — the canonical slot
    /// order of the reverse exchange.
    boundary_edges: Vec<u32>,
    /// `owner(dst)` per boundary edge (the rank that computes its row).
    boundary_edge_owners: Vec<u8>,
}

impl HaloPlan {
    /// Build the plan for one structure. `segments` comes from
    /// [`crate::data::featurized::FeaturizedStore::segments`]; `edges` is
    /// the structure's radius graph in its canonical `(src, dst)`-sorted
    /// order.
    pub fn build(segments: &[u8], edges: &[Edge], world: usize) -> HaloPlan {
        assert!(matches!(world, 1 | 2 | 4 | 8), "graph-par world must be 1, 2, 4 or 8");
        let owners: Vec<usize> =
            segments.iter().map(|&s| segment_owner(s, world)).collect();
        let mut is_boundary = vec![false; owners.len()];
        let mut boundary_edges = Vec::new();
        let mut boundary_edge_owners = Vec::new();
        for (ei, e) in edges.iter().enumerate() {
            let (s, d) = (e.src as usize, e.dst as usize);
            if owners[s] != owners[d] {
                is_boundary[s] = true;
                boundary_edges.push(ei as u32);
                boundary_edge_owners.push(owners[d] as u8);
            }
        }
        let mut boundary_atoms: Vec<u32> = (0..owners.len() as u32)
            .filter(|&a| is_boundary[a as usize])
            .collect();
        boundary_atoms.sort_by_key(|&a| (owners[a as usize], a));
        HaloPlan { world, owners, boundary_atoms, boundary_edges, boundary_edge_owners }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Owning rank of `atom`.
    #[inline]
    pub fn owner(&self, atom: usize) -> usize {
        self.owners[atom]
    }

    /// Whether `rank` owns `atom` (i.e. computes its node work).
    #[inline]
    pub fn owns(&self, rank: usize, atom: usize) -> bool {
        self.owners[atom] == rank
    }

    /// Atoms whose hidden state crosses ranks each block (canonical order).
    pub fn boundary_atoms(&self) -> &[u32] {
        &self.boundary_atoms
    }

    /// Cross-owner edges (canonical order of the reverse exchange).
    pub fn boundary_edges(&self) -> &[u32] {
        &self.boundary_edges
    }

    /// Exchange `width` features per boundary atom from the node-major
    /// array `data` (length `natoms * width`): each boundary atom's owner
    /// deposits its row, every rank receives the owner's exact bits. No-op
    /// (zero traffic) when the boundary is empty — in particular at
    /// world 1.
    pub fn exchange_node_rows(
        &self,
        comm: &Comm,
        data: &mut [f64],
        width: usize,
    ) -> Result<(), CommError> {
        if self.boundary_atoms.is_empty() {
            return Ok(());
        }
        let rank = comm.rank_in_group;
        let mut buf = vec![0.0f64; self.boundary_atoms.len() * width];
        for (slot, &a) in self.boundary_atoms.iter().enumerate() {
            if self.owners[a as usize] == rank {
                buf[slot * width..][..width]
                    .copy_from_slice(&data[a as usize * width..][..width]);
            }
        }
        comm.allreduce_sum_f64(&mut buf)?;
        for (slot, &a) in self.boundary_atoms.iter().enumerate() {
            data[a as usize * width..][..width]
                .copy_from_slice(&buf[slot * width..][..width]);
        }
        Ok(())
    }

    /// Exchange the first `width` columns of every boundary edge's row in
    /// the edge-major array `data` (row stride `stride >= width`): the
    /// edge's `owner(dst)` — the rank that computed the row — deposits,
    /// every rank receives. Used by the reverse halo (the `d_x` src-part
    /// gradient rows of the analytic backward).
    pub fn exchange_edge_rows(
        &self,
        comm: &Comm,
        data: &mut [f64],
        stride: usize,
        width: usize,
    ) -> Result<(), CommError> {
        debug_assert!(width <= stride);
        if self.boundary_edges.is_empty() {
            return Ok(());
        }
        let rank = comm.rank_in_group;
        let mut buf = vec![0.0f64; self.boundary_edges.len() * width];
        for (slot, &ei) in self.boundary_edges.iter().enumerate() {
            if self.boundary_edge_owners[slot] as usize == rank {
                buf[slot * width..][..width]
                    .copy_from_slice(&data[ei as usize * stride..][..width]);
            }
        }
        comm.allreduce_sum_f64(&mut buf)?;
        for (slot, &ei) in self.boundary_edges.iter().enumerate() {
            data[ei as usize * stride..][..width]
                .copy_from_slice(&buf[slot * width..][..width]);
        }
        Ok(())
    }

    /// Exact f64 elements this plan moves through `Comm` for ONE training
    /// step: `layers` forward node exchanges (boundary atoms x hidden),
    /// `layers` reverse edge exchanges (boundary edges x hidden), the
    /// [`LOSS_SLOTS`] loss fold and the `8 * param_len` segmented gradient
    /// fold. Confronted against the measured [`Comm::stats`] delta by the
    /// scalesim tests and the graph-parallel bench.
    pub fn predicted_step_elems(&self, hidden: usize, layers: usize, param_len: usize) -> u64 {
        let halo = (self.boundary_atoms.len() + self.boundary_edges.len())
            * hidden
            * layers;
        (halo + LOSS_SLOTS + SEGMENTS * param_len) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collectives::run_group;

    /// Chain graph 0-1-2-3 (both directions) with hand-placed segments.
    fn chain_edges() -> Vec<Edge> {
        let mk = |src: u32, dst: u32| Edge {
            src,
            dst,
            rel_hat: [1.0, 0.0, 0.0],
            dist: 1.0,
        };
        // (src, dst)-sorted like radius_graph output.
        vec![mk(0, 1), mk(1, 0), mk(1, 2), mk(2, 1), mk(2, 3), mk(3, 2)]
    }

    #[test]
    fn segment_ownership_rule() {
        for seg in 0..8u8 {
            assert_eq!(segment_owner(seg, 1), 0);
            assert_eq!(segment_owner(seg, 8), seg as usize);
        }
        assert_eq!(segment_owner(3, 2), 0);
        assert_eq!(segment_owner(4, 2), 1);
        assert_eq!(segment_owner(1, 4), 0);
        assert_eq!(segment_owner(2, 4), 1);
        assert_eq!(segment_owner(7, 4), 3);
    }

    #[test]
    fn plan_finds_boundary_atoms_and_edges() {
        // Atoms 0,1 in segment 0 (rank 0 at world 2), atoms 2,3 in segment
        // 4 (rank 1): the cross edges are 1->2 and 2->1 (indices 2, 3).
        let plan = HaloPlan::build(&[0, 0, 4, 4], &chain_edges(), 2);
        assert_eq!(plan.boundary_atoms(), &[1, 2]);
        assert_eq!(plan.boundary_edges(), &[2, 3]);
        assert_eq!(plan.owner(1), 0);
        assert_eq!(plan.owner(2), 1);
        assert!(plan.owns(0, 0) && !plan.owns(1, 0));
    }

    #[test]
    fn world_one_has_no_boundary() {
        let plan = HaloPlan::build(&[0, 2, 5, 7], &chain_edges(), 1);
        assert!(plan.boundary_atoms().is_empty());
        assert!(plan.boundary_edges().is_empty());
        let comms = crate::comm::Comm::group(1);
        let mut data = vec![1.25f64; 4 * 3];
        plan.exchange_node_rows(&comms[0], &mut data, 3).unwrap();
        assert_eq!(comms[0].stats().elems, 0, "empty boundary moves nothing");
    }

    #[test]
    fn node_exchange_delivers_owner_bits_to_everyone() {
        let plan = std::sync::Arc::new(HaloPlan::build(&[0, 0, 4, 4], &chain_edges(), 2));
        let width = 3;
        let results = run_group(2, |c| {
            let rank = c.rank_in_group;
            // Owned rows hold rank-specific irrational-ish values; remote
            // rows hold garbage that must be overwritten.
            let mut data = vec![-99.0f64; 4 * width];
            for a in 0..4 {
                if plan.owns(rank, a) {
                    for k in 0..width {
                        data[a * width + k] = (rank * 100 + a * 10 + k) as f64 + 0.1;
                    }
                }
            }
            plan.exchange_node_rows(&c, &mut data, width).unwrap();
            (data, c.stats())
        });
        let mut outs = Vec::new();
        for r in results {
            let (data, st) = r.unwrap();
            // Boundary atom 1 owned by rank 0, atom 2 by rank 1.
            assert_eq!(&data[width..2 * width], &[10.1, 11.1, 12.1]);
            assert_eq!(&data[2 * width..3 * width], &[120.1, 121.1, 122.1]);
            // Non-boundary remote rows stay untouched (never exchanged).
            assert_eq!(st.elems, (2 * width) as u64);
            outs.push(data);
        }
        // Bit-identical across ranks on the exchanged rows.
        for k in width..3 * width {
            assert_eq!(outs[0][k].to_bits(), outs[1][k].to_bits());
        }
    }

    #[test]
    fn edge_exchange_fills_src_part_from_dst_owner() {
        let plan = std::sync::Arc::new(HaloPlan::build(&[0, 0, 4, 4], &chain_edges(), 2));
        let (stride, width) = (5, 2);
        let results = run_group(2, |c| {
            let rank = c.rank_in_group;
            let edges = chain_edges();
            let mut data = vec![0.0f64; edges.len() * stride];
            for (ei, e) in edges.iter().enumerate() {
                if plan.owns(rank, e.dst as usize) {
                    for k in 0..stride {
                        data[ei * stride + k] = (rank * 100 + ei * 10 + k) as f64 + 0.5;
                    }
                }
            }
            plan.exchange_edge_rows(&c, &mut data, stride, width).unwrap();
            data
        });
        for r in results {
            let data = r.unwrap();
            // Edge 2 (1->2): dst 2 owned by rank 1 -> rows from rank 1.
            assert_eq!(&data[2 * stride..2 * stride + width], &[120.5, 121.5]);
            // Edge 3 (2->1): dst 1 owned by rank 0 -> rows from rank 0.
            assert_eq!(&data[3 * stride..3 * stride + width], &[30.5, 31.5]);
        }
    }

    #[test]
    fn predicted_elems_formula() {
        let plan = HaloPlan::build(&[0, 0, 4, 4], &chain_edges(), 2);
        // 2 boundary atoms + 2 boundary edges, hidden 16, 4 layers, 10
        // param elems: (2+2)*16*4 + 24 + 80.
        assert_eq!(plan.predicted_step_elems(16, 4, 10), 256 + 24 + 80);
    }
}
