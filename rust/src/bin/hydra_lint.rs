//! `hydra_lint` — the blocking static-analysis gate (see
//! `hydra_mtp::lint` for the five rules). Walks the source tree, prints
//! `file:line` diagnostics for every violation, writes the
//! machine-readable `LINT_report.json`, and exits nonzero when any
//! unannotated violation exists. Lints its own sources like any others.
//!
//! ```text
//! hydra_lint [--root DIR] [--json PATH] [--quiet]
//! ```
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use hydra_mtp::lint;

const HELP: &str = "hydra_lint: static invariant checks for hydra-mtp

USAGE:
    hydra_lint [--root DIR] [--json PATH] [--quiet]

OPTIONS:
    --root DIR    Source root to scan (default: rust/src, else src)
    --json PATH   Report path (default: LINT_report.json)
    --quiet       Suppress human diagnostics (exit code + JSON only)
    --help        Show this help

Rules: nondeterministic, panic, collective, config, env (see the
lint module docs for scopes and the lint:allow annotation grammar).
";

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json_path = PathBuf::from("LINT_report.json");
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage("--root needs a value"),
            },
            "--json" => match args.next() {
                Some(v) => json_path = PathBuf::from(v),
                None => return usage("--json needs a value"),
            },
            "--quiet" => quiet = true,
            "--help" | "-h" => {
                print!("{HELP}");
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }
    let root = root.unwrap_or_else(default_root);
    if !root.is_dir() {
        eprintln!("hydra_lint: scan root {} is not a directory", root.display());
        return ExitCode::from(2);
    }
    let report = match lint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("hydra_lint: {e:#}");
            return ExitCode::from(2);
        }
    };
    if let Err(e) = std::fs::write(&json_path, report.to_json().to_string()) {
        eprintln!("hydra_lint: cannot write {}: {e}", json_path.display());
        return ExitCode::from(2);
    }
    if !quiet {
        print!("{}", report.render_human());
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn default_root() -> PathBuf {
    let preferred = PathBuf::from("rust/src");
    if preferred.is_dir() {
        preferred
    } else {
        PathBuf::from("src")
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("hydra_lint: {msg}\n\n{HELP}");
    ExitCode::from(2)
}
