//! hydra-mtp launcher: the L3 coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §5):
//!
//!   datagen   generate the registered synthetic datasets into GPack files
//!   train     train one model (any of the seven modes) through `Session`
//!   table1    regenerate Table 1 (energy MAE matrix, trains 7 models)
//!   table2    regenerate Table 2 (force MAE matrix, same runs)
//!   fig1      element-frequency heatmap over the aggregated datasets
//!   fig4      weak/strong scaling sweeps on Frontier/Perlmutter/Aurora
//!   serve     run the always-on batched-inference server over a request stream
//!   loadtest  measure coalesced-vs-sequential serving latency + throughput
//!   tasks     print the task registry (the five presets + custom tasks)
//!   info      print manifest / architecture / memory-regime summary
//!
//! Unknown/misspelled `--flags` are rejected with the valid flag list for
//! the subcommand (a typo like `--replica 4` used to silently win defaults).

use std::sync::Arc;

use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::experiments;
use hydra_mtp::coordinator::trainer::TrainedModel;
use hydra_mtp::data::structures::{AtomicStructure, ALL_DATASETS};
use hydra_mtp::data::{generators, pack};
use hydra_mtp::model::arch;
use hydra_mtp::scalesim;
use hydra_mtp::serve::loadtest;
use hydra_mtp::session::Session;
use hydra_mtp::tasks::TaskRegistry;
use hydra_mtp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "datagen" => cmd_datagen(&args),
        "train" => cmd_train(&args),
        "table1" => cmd_tables(&args, true),
        "table2" => cmd_tables(&args, false),
        "fig1" => cmd_fig1(&args),
        "fig4" => cmd_fig4(&args),
        "serve" => cmd_serve(&args),
        "loadtest" => cmd_loadtest(&args),
        "tasks" => cmd_tasks(&args),
        "info" => cmd_info(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "hydra-mtp — multi-task parallelism for GFM pre-training (paper reproduction)

USAGE: hydra-mtp <command> [--flags]

COMMANDS
  datagen  --out DIR [--per-dataset N] [--seed S] [--max-atoms A]
  train    --mode MODE [--config FILE] [--epochs N] [--replicas M]
           [--per-dataset N] [--seed S] [--lr LR] [--backend auto|native|pjrt]
           [--precision f64|mixed-f32] [--artifacts DIR] [--csv FILE]
           [--checkpoint-dir DIR] [--checkpoint-every N] [--resume PATH|latest]
           [--faults SPEC] [--max-restarts N]
           [--overlap [BOOL]] [--bucket-elems N] [--elastic [BOOL]]
           [--graph-par [BOOL]]
           MODE: ANI1x|QM7-X|Transition1x|MPTrj|Alexandria|baseline-all|mtl-base|mtl-par
                 |Supercell|AmorphousBox (large-structure presets, any custom task)
           --backend native (the default resolution on artifact-less machines)
           trains with the pure-rust EGNN engine: no artifacts, no PJRT;
           --backend pjrt requires `make artifacts` + `--features pjrt`
           --precision mixed-f32 runs the native engine's blocked f32
           microkernels (f64 accumulation); f64 is the gradcheck oracle.
           Checkpoints record the precision: resume across precisions is refused
           --checkpoint-dir writes CRC-guarded epoch_NNNN.ckpt files; --resume
           restarts bit-identically from a checkpoint file (or the newest in a
           dir); --resume latest scans --checkpoint-dir for the newest CRC-valid
           file, skipping corrupt/truncated ones
           Training runs under rank-failure supervision: a dead or stalled rank
           surfaces as a typed error and the run restarts from the latest valid
           checkpoint, up to --max-restarts times. --faults injects
           deterministic faults for drills (also env HYDRA_MTP_FAULTS), e.g.
           'rank-panic@rank=1,epoch=2,step=0;corrupt-ckpt@epoch=2' — kinds:
           rank-panic, stall, nonfinite, corrupt-ckpt, serve-panic
           --overlap reduces gradient buckets on a per-rank comm thread while
           backward still runs (bit-identical to the sync path; also env
           HYDRA_MTP_OVERLAP); --bucket-elems caps a bucket's f32 payload;
           --elastic (mtl-par only) re-sizes each head's sub-group at epoch
           boundaries from its dataset's measured per-step cost EMA
           --graph-par (single-branch modes, --replicas 1|2|4|8) domain-
           decomposes each structure's atoms across ranks with per-layer halo
           exchange instead of replicating graphs; results are bit-identical
           to --replicas 1 at every world size (pure-f64 math). The path for
           structures too large for one rank, e.g. --mode supercell
  table1   [--epochs N] [--per-dataset N] [--replicas M] [--backend B] [--csv FILE]
  table2   (same flags; same training runs, force metric)
  fig1     [--per-dataset N] [--seed S] [--max-atoms A]
  fig4     [--machine all|frontier|perlmutter|aurora] [--csv FILE] [--seed S]
  serve    [--model CKPT] [--data GPACK] [--requests N] [--clients C]
           [--workers W] [--queue-capacity Q] [--wait-ms MS]
           Always-on batched inference: C concurrent clients submit one
           structure at a time; a persistent worker pool coalesces
           concurrent requests into shared padded batches (admission by
           node/edge budget). Without --model a deterministic synthetic
           model serves every registered task; without --data the held-out
           test split is replayed. Outputs are bit-identical to sequential
           Predictor calls
  loadtest (serve flags + [--budget-ms MS] [--json FILE])
           Same request stream through sequential predict_one AND the
           server in one process; prints p50/p95/p99 latency, sustained
           structures/sec, speedup and the bit-identity verdict
  tasks    (print the task registry: palettes, generator families, fidelity)
  info     [--artifacts DIR]

Misspelled flags are rejected with the valid list for the subcommand."
    );
    // Rendered from the central registry (lint/env_registry.rs): hydra-lint
    // R5 fails the build if an env read exists that this table omits, so the
    // help below cannot drift from the code.
    println!("\n{}", hydra_mtp::lint::env_registry::help_text());
}

/// Flags shared by the config-driven subcommands.
const CONFIG_FLAGS: [&str; 9] = [
    "config",
    "artifacts",
    "backend",
    "precision",
    "epochs",
    "replicas",
    "per-dataset",
    "seed",
    "lr",
];

fn base_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    cfg.artifacts_dir = args.str("artifacts", &cfg.artifacts_dir);
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = hydra_mtp::runtime::BackendKind::parse(b)?;
    }
    if let Some(p) = args.opt_str("precision") {
        cfg.precision = hydra_mtp::runtime::Precision::parse(p)?;
    }
    if let Some(e) = args.opt_str("epochs") {
        cfg.train.epochs = e.parse()?;
    }
    if let Some(r) = args.opt_str("replicas") {
        cfg.parallel.replicas = r.parse()?;
    }
    if let Some(n) = args.opt_str("per-dataset") {
        cfg.data.per_dataset = n.parse()?;
    }
    if let Some(s) = args.opt_str("seed") {
        cfg.data.seed = s.parse()?;
    }
    if let Some(lr) = args.opt_str("lr") {
        cfg.train.lr = lr.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_datagen(args: &Args) -> anyhow::Result<()> {
    args.ensure_known("datagen", &["out", "per-dataset", "seed", "max-atoms"])?;
    let out = args.str("out", "data");
    let per = args.usize("per-dataset", 1000);
    let seed = args.u64("seed", 2025);
    let max_atoms = args.usize("max-atoms", 24);
    std::fs::create_dir_all(&out)?;
    let cfg = generators::GeneratorConfig { max_atoms, ..Default::default() };
    // Every registered task (the five presets plus runtime registrations),
    // one GPack file each.
    for (d, samples) in generators::generate_all(seed, per, &cfg) {
        let path = format!("{out}/{}.gpack", d.name().to_lowercase().replace('-', ""));
        let n = pack::write_all(&path, &samples)?;
        let hist = generators::element_histogram(&samples);
        let coverage = hist.iter().filter(|&&c| c > 0).count();
        println!(
            "{:<14} {n:>7} structures -> {path}  ({} elements, {} atoms total)",
            d.name(),
            coverage,
            hist.iter().sum::<u64>()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut allowed = vec![
        "mode",
        "csv",
        "checkpoint-dir",
        "checkpoint-every",
        "resume",
        "faults",
        "max-restarts",
        "overlap",
        "bucket-elems",
        "elastic",
        "graph-par",
    ];
    allowed.extend(CONFIG_FLAGS);
    args.ensure_known("train", &allowed)?;

    // The large-structure presets (Supercell / AmorphousBox) are runtime
    // registrations, so `--mode supercell` must see them before parse.
    hydra_mtp::tasks::register_large_presets()?;
    let mut cfg = base_config(args)?;
    cfg.mode = TrainMode::parse(&args.str("mode", "mtl-par"))?;
    if let Some(dir) = args.opt_str("checkpoint-dir") {
        cfg.checkpoint.dir = Some(dir.to_string());
    }
    if let Some(every) = args.opt_str("checkpoint-every") {
        cfg.checkpoint.every = every.parse()?;
    }
    if let Some(path) = args.opt_str("resume") {
        cfg.checkpoint.resume = Some(path.to_string());
    }
    if let Some(spec) = args.opt_str("faults") {
        cfg.fault.spec = Some(spec.to_string());
    }
    if let Some(n) = args.opt_str("max-restarts") {
        cfg.fault.max_restarts = n.parse()?;
    }
    // `--overlap` / `--elastic` alone mean true; `--overlap false` turns a
    // config-file setting back off.
    if args.flags.contains_key("overlap") {
        cfg.parallel.overlap = args.bool("overlap");
    }
    if let Some(n) = args.opt_str("bucket-elems") {
        cfg.parallel.bucket_elems = n.parse()?;
    }
    if args.flags.contains_key("elastic") {
        cfg.parallel.elastic = args.bool("elastic");
    }
    if args.flags.contains_key("graph-par") {
        cfg.parallel.graph_par = args.bool("graph-par");
    }
    cfg.validate()?;
    println!("loading engine ({} backend requested) ...", cfg.backend.name());
    let mut session = Session::builder().config(cfg).build()?;
    println!(
        "backend: {} ({}, precision {}); generating data ...",
        session.engine().backend_name(),
        session.engine().platform(),
        session.engine().precision().name()
    );
    // Generate outside the timer so "trained in" stays comparable with
    // seed-era logs (training only, no data generation).
    session.generate_data();
    let t0 = std::time::Instant::now();
    let outcome = session.train_with_recovery()?;
    println!("\n=== {} ===", outcome.model.name);
    for e in &outcome.log.epochs {
        println!("{}", e.summary());
    }
    println!(
        "trained in {:?}; global allreduce traffic {:.1} Mf32, head-group {:.1} Mf32",
        t0.elapsed(),
        outcome.comm_elems.0 as f64 / 1e6,
        outcome.comm_elems.1 as f64 / 1e6
    );
    if outcome.overlapped_elems > 0 {
        println!(
            "overlapped reduction hid {:.1} Mf32 of that traffic behind backward",
            outcome.overlapped_elems as f64 / 1e6
        );
    }
    if !outcome.final_head_sizes.is_empty() {
        println!(
            "elastic head sub-group sizes (final epoch): {:?}",
            outcome.final_head_sizes
        );
    }
    if let Some(path) = args.opt_str("csv") {
        std::fs::write(path, outcome.log.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_tables(args: &Args, energy: bool) -> anyhow::Result<()> {
    let mut allowed = vec!["csv"];
    allowed.extend(CONFIG_FLAGS);
    args.ensure_known(if energy { "table1" } else { "table2" }, &allowed)?;

    let cfg = base_config(args)?;
    // One session supplies the engine + shared data bundle; run_tables
    // trains each of the seven modes through its own Session on top. The
    // bundle must always cover all five datasets regardless of cfg.mode
    // (a config file saved from a single-dataset run would otherwise
    // shrink it), so pin the task list explicitly.
    let mut session = Session::builder()
        .config(cfg.clone())
        .tasks(&ALL_DATASETS)
        .build()?;
    session.generate_data();
    println!(
        "training the 7 models of Section 5.1 ({} samples/dataset, {} epochs max) ...",
        cfg.data.per_dataset, cfg.train.epochs
    );
    let engine = Arc::clone(session.engine());
    let data = session.data().expect("generated above");
    let matrix = experiments::run_tables(&engine, &cfg, data, |line| println!("  {line}"))?;
    println!("\n{}", matrix.render(energy));
    if let Some(path) = args.opt_str("csv") {
        std::fs::write(path, matrix.to_csv(energy))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_fig1(args: &Args) -> anyhow::Result<()> {
    args.ensure_known("fig1", &["per-dataset", "seed", "max-atoms"])?;
    let per = args.usize("per-dataset", 500);
    let seed = args.u64("seed", 2025);
    let counts = experiments::fig1_histogram(seed, per, args.usize("max-atoms", 24));
    println!("{}", experiments::fig1_render(&counts));
    Ok(())
}

fn cmd_fig4(args: &Args) -> anyhow::Result<()> {
    args.ensure_known("fig4", &["machine", "csv", "seed"])?;
    let seed = args.u64("seed", 2025);
    let w = scalesim::Workload::paper(5);
    let which = args.str("machine", "all");
    let rows = if which == "all" {
        scalesim::fig4_all(&w, seed)
    } else {
        let m = scalesim::machine_by_name(&which)
            .ok_or_else(|| anyhow::anyhow!("unknown machine '{which}'"))?;
        let mut rows = scalesim::weak_scaling(&m, &w, &[160, 320, 640], 100, seed);
        rows.extend(scalesim::strong_scaling(&m, &w, &[10240, 20480], 1_000_000, seed));
        rows
    };
    let machines: Vec<&str> = if which == "all" {
        vec!["Frontier", "Perlmutter", "Aurora"]
    } else {
        vec![scalesim::machine_by_name(&which).unwrap().name]
    };
    for m in machines {
        println!("{}", scalesim::render_panel(&rows, m, "weak"));
        println!("{}", scalesim::render_panel(&rows, m, "strong"));
    }
    if let Some(path) = args.opt_str("csv") {
        std::fs::write(path, scalesim::to_csv(&rows))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Flags shared by `serve` and `loadtest`.
const SERVE_FLAGS: [&str; 7] = [
    "model",
    "data",
    "requests",
    "clients",
    "workers",
    "queue-capacity",
    "wait-ms",
];

/// Apply the serve CLI overrides onto `cfg.serve`.
fn serve_overrides(args: &Args, cfg: &mut RunConfig) -> anyhow::Result<()> {
    cfg.serve.workers = args.usize("workers", cfg.serve.workers);
    cfg.serve.queue_capacity = args.usize("queue-capacity", cfg.serve.queue_capacity);
    cfg.serve.enqueue_wait_ms = args.u64("wait-ms", cfg.serve.enqueue_wait_ms);
    cfg.serve.latency_budget_ms = args.f64("budget-ms", cfg.serve.latency_budget_ms);
    cfg.validate()
}

/// Resolve the model (`--model CKPT` or a deterministic synthetic one) and
/// the request stream (`--data GPACK` or the held-out test split), cycled
/// to exactly `requests` structures the model can serve.
fn serving_inputs(
    args: &Args,
    session: &mut Session,
    requests: usize,
) -> anyhow::Result<(TrainedModel, Vec<AtomicStructure>)> {
    let model = match args.opt_str("model") {
        Some(path) => Session::load_model(path)?,
        None => loadtest::synthetic_model(
            session.engine(),
            session.tasks(),
            session.config().data.seed,
        ),
    };
    let mut structures = match args.opt_str("data") {
        Some(path) => pack::read_all(path)?,
        None => session.test_samples(requests)?,
    };
    structures.retain(|s| model.try_branch_for(s.dataset).is_some());
    anyhow::ensure!(
        !structures.is_empty(),
        "no structures to serve: none of the inputs match a head of model '{}'",
        model.name
    );
    if structures.len() > requests {
        structures.truncate(requests);
    } else {
        let base = structures.clone();
        while structures.len() < requests {
            let take = requests - structures.len();
            structures.extend(base.iter().take(take).cloned());
        }
    }
    Ok((model, structures))
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut allowed = SERVE_FLAGS.to_vec();
    allowed.extend(CONFIG_FLAGS);
    args.ensure_known("serve", &allowed)?;

    let mut cfg = base_config(args)?;
    serve_overrides(args, &mut cfg)?;
    let requests = args.usize("requests", 64);
    let clients = args.usize("clients", 4).max(1);
    let mut session = Session::builder().config(cfg).build()?;
    let (model, structures) = serving_inputs(args, &mut session, requests)?;
    println!(
        "serving model '{}' on the {} backend (precision {}): {} requests, {} clients ...",
        model.name,
        session.engine().backend_name(),
        session.engine().precision().name(),
        structures.len(),
        clients
    );
    let server = session.server(&model)?;
    let t0 = std::time::Instant::now();
    let errors: usize = std::thread::scope(|scope| {
        let (server, structures) = (&server, structures.as_slice());
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || {
                    structures
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| i % clients == c)
                        .filter(|(_, s)| server.predict(s).is_err())
                        .count()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).sum()
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    server.shutdown();
    println!(
        "served {} / rejected {} in {:.3}s ({:.1} structures/s) over {} batches \
         (avg {:.2} structures/batch); {} client errors",
        stats.served,
        stats.rejected,
        wall,
        stats.served as f64 / wall.max(1e-9),
        stats.batches,
        stats.avg_batch(),
        errors
    );
    if hydra_mtp::fault::FaultPlan::from_env()?.is_empty() {
        anyhow::ensure!(errors == 0, "{errors} requests failed");
    } else {
        // Chaos mode (HYDRA_MTP_FAULTS set): the injected worker panic is
        // the point. Require that it fired, was answered, and the worker
        // recovered — CI's end-to-end serve-respawn check.
        println!(
            "chaos: {} worker respawn(s), {} request(s) answered with the \
             typed internal error",
            stats.respawned, stats.internal_errors
        );
        anyhow::ensure!(stats.respawned >= 1, "injected serve fault never fired");
        anyhow::ensure!(
            stats.served >= 1,
            "server did not recover after the injected panic"
        );
    }
    Ok(())
}

fn cmd_loadtest(args: &Args) -> anyhow::Result<()> {
    let mut allowed = vec!["budget-ms", "json"];
    allowed.extend(SERVE_FLAGS);
    allowed.extend(CONFIG_FLAGS);
    args.ensure_known("loadtest", &allowed)?;

    let mut cfg = base_config(args)?;
    serve_overrides(args, &mut cfg)?;
    let requests = args.usize("requests", 64);
    let clients = args.usize("clients", 4).max(1);
    let serve_cfg = cfg.serve;
    let mut session = Session::builder().config(cfg).build()?;
    let (model, structures) = serving_inputs(args, &mut session, requests)?;
    println!(
        "load test: model '{}', {} backend, precision {}, {} requests, {} clients",
        model.name,
        session.engine().backend_name(),
        session.engine().precision().name(),
        structures.len(),
        clients
    );
    let report =
        loadtest::run_loadtest(session.engine(), &model, &structures, clients, serve_cfg)?;
    for (name, leg) in [("sequential", &report.sequential), ("server", &report.server)] {
        println!(
            "  {name:<10} p50 {:>8.3}ms  p95 {:>8.3}ms  p99 {:>8.3}ms  {:>8.1} structures/s  \
             (avg batch {:.2})",
            leg.p50_ns as f64 / 1e6,
            leg.p95_ns as f64 / 1e6,
            leg.p99_ns as f64 / 1e6,
            leg.throughput_per_sec,
            leg.avg_batch
        );
    }
    println!(
        "  speedup {:.2}x, bit-identical: {}, latency budget {:.1}ms ({})",
        report.speedup(),
        report.bit_identical,
        serve_cfg.latency_budget_ms,
        if report.server.p99_ns as f64 / 1e6 <= serve_cfg.latency_budget_ms {
            "met"
        } else {
            "EXCEEDED"
        }
    );
    if let Some(path) = args.opt_str("json") {
        std::fs::write(path, format!("{}\n", report.to_json()))?;
        println!("wrote {path}");
    }
    anyhow::ensure!(report.bit_identical, "server outputs diverged from the sequential baseline");
    Ok(())
}

fn cmd_tasks(args: &Args) -> anyhow::Result<()> {
    args.ensure_known("tasks", &[])?;
    let reg = TaskRegistry::global();
    println!(
        "{} registered tasks ({} built-in presets):\n",
        reg.len(),
        ALL_DATASETS.len()
    );
    println!(
        "{:<3} {:<16} {:<10} {:>7} {:>6} {:>8} {:>7}",
        "#", "name", "family", "elems", "relax", "perturb", "tag"
    );
    for d in reg.all() {
        let s = reg.spec(d);
        let family = if d.is_inorganic() { "crystal" } else { "molecule" };
        println!(
            "{:<3} {:<16} {:<10} {:>7} {:>6} {:>8.2} {:>7}",
            d.index(),
            s.name,
            family,
            s.palette.len(),
            s.generator.relax_steps,
            s.generator.perturb_factor,
            s.fidelity.seed_tag
        );
    }
    println!(
        "\nRegister more tasks at runtime via TaskRegistry::global().register(TaskSpec::new(..))."
    );
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    args.ensure_known("info", &["artifacts"])?;
    let dir = args.str("artifacts", "artifacts");
    let manifest = match hydra_mtp::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {dir} (PJRT-capable with --features pjrt)");
            m
        }
        Err(e) => {
            println!("no compiled artifacts at '{dir}' ({e:#})");
            println!("showing the native backend's synthesized manifest instead:");
            hydra_mtp::runtime::Manifest::synthesize(
                hydra_mtp::runtime::ManifestConfig::default_native(),
            )
        }
    };
    manifest.validate()?;
    let c = manifest.config;
    println!(
        "model: {} EGNN layers, hidden {}, head 3x{}, cutoff {}",
        c.num_layers, c.hidden, c.head_hidden, c.cutoff
    );
    println!(
        "batch: {} nodes / {} edges / {} graphs",
        c.max_nodes, c.max_edges, c.max_graphs
    );
    let dims = c.arch_dims();
    println!(
        "P_s = {} params, P_h = {} params",
        dims.shared_params(),
        dims.head_params()
    );
    for n_heads in [1usize, 5, 20] {
        let regime = arch::classify_regime(&dims, n_heads, 4.0);
        println!(
            "  {} heads: total {:>9}, mem/GPU {:>6.1} MiB (DDP) vs {:>6.1} MiB (MTP) -> {:?}",
            n_heads,
            dims.total_params(n_heads),
            arch::memory_without_mtp(&dims, n_heads) as f64 / (1 << 20) as f64,
            arch::memory_with_mtp(&dims) as f64 / (1 << 20) as f64,
            regime
        );
    }
    let paper = arch::ArchDims::paper();
    println!(
        "paper config: P_s = {:.1}M, P_h = {:.1}M, 5 heads total {:.1}M params",
        paper.shared_params() as f64 / 1e6,
        paper.head_params() as f64 / 1e6,
        paper.total_params(5) as f64 / 1e6
    );
    if manifest.is_synthesized() {
        println!("backend: native (pure-rust EGNN engine; no artifact files needed)");
    }
    for (name, art) in &manifest.artifacts {
        println!(
            "artifact {:<13} {} inputs, {} outputs, sha256 {}",
            name,
            art.inputs.len(),
            art.outputs.len(),
            &art.sha256[..12.min(art.sha256.len())]
        );
    }
    Ok(())
}
