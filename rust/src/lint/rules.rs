//! The five hydra-lint rules. Each pushes [`Finding`]s; a finding on a
//! line covered by a matching `lint:allow` annotation is recorded as an
//! *allowed* site (reported, non-fatal) instead of a violation.
//!
//! | rule               | scope                                   | catches |
//! |--------------------|-----------------------------------------|---------|
//! | `nondeterministic` | model/egnn, model/kernels, comm/,       | `HashMap`/`HashSet`, `Instant::now` |
//! |                    | checkpoint, data/graph                  | |
//! | `panic`            | serve/, checkpoint, coordinator/trainer | `unwrap`/`expect`/panic macros; raw range-indexing (serve/ + checkpoint) |
//! | `collective`       | every file                              | a collective result unwrapped or discarded |
//! | `config`           | config.rs                               | a `RunConfig` leaf in neither the fingerprint nor `FINGERPRINT_EXCLUDED` |
//! | `env`              | every file                              | `HYDRA_MTP_*` reads missing from the registry, and stale registry entries |
//!
//! Only the first three are annotation-suppressible: `config` and `env`
//! are table-driven — the fix is to update the table, not to annotate.

use std::collections::{BTreeMap, BTreeSet};

use crate::lint::env_registry::EnvVar;
use crate::lint::scan::SourceFile;
use crate::lint::Finding;

/// Rule names a `lint:allow` annotation may name.
pub const ALLOWABLE_RULES: &[&str] = &["nondeterministic", "panic", "collective"];

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whether `code` contains `needle` at identifier boundaries (so `HashMap`
/// does not match `MyHashMapLike`). Needles may contain `::`.
fn has_word(code: &str, needle: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code.get(from..).and_then(|s| s.find(needle)) {
        let at = from + p;
        let end = at + needle.len();
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Build a finding, consuming a covering annotation when one exists.
fn finding(f: &SourceFile, idx: usize, rule: &'static str, message: String) -> Finding {
    let allow = f.allow_for(idx, rule);
    Finding {
        rule,
        file: f.rel_path.clone(),
        line: idx + 1,
        message,
        allowed_reason: allow.map(|a| a.reason.clone()),
        allow_decl_line: allow.map(|a| a.decl_line),
    }
}

// ---------------------------------------------------------------------------
// R1: determinism
// ---------------------------------------------------------------------------

const R1_FILES: &[&str] = &["model/egnn.rs", "model/kernels.rs", "checkpoint.rs", "data/graph.rs"];
const R1_TOKENS: &[&str] = &["HashMap", "HashSet", "Instant::now"];

fn r1_in_scope(path: &str) -> bool {
    path.starts_with("comm/") || R1_FILES.contains(&path)
}

/// R1: no arbitrary-order containers and no wall-clock reads in the
/// modules whose outputs must be bit-reproducible. `BTreeMap`/`BTreeSet`
/// iterate in key order and are the sanctioned replacements; wall-clock
/// use that provably never feeds ordering (timeout deadlines) carries an
/// annotation saying so.
pub fn r1_determinism(f: &SourceFile, out: &mut Vec<Finding>) {
    if !r1_in_scope(&f.rel_path) {
        return;
    }
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for tok in R1_TOKENS {
            if has_word(&line.code, tok) {
                out.push(finding(
                    f,
                    idx,
                    "nondeterministic",
                    format!("`{tok}` in a determinism-critical module"),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// R2: panic safety
// ---------------------------------------------------------------------------

const R2_DOT_TOKENS: &[&str] = &[".unwrap()", ".expect("];
const R2_MACRO_TOKENS: &[&str] = &["panic!", "unreachable!", "todo!", "unimplemented!"];

fn r2_in_scope(path: &str) -> bool {
    path.starts_with("serve/") || path == "checkpoint.rs" || path == "coordinator/trainer.rs"
}

/// The raw range-index leg applies where untrusted lengths flow (decoding
/// checkpoint bytes, serving request payloads). The trainer's
/// flatten/unflatten helpers slice layouts computed in the same function
/// — bounds-proven by construction and pervasive — so the trainer is
/// covered by the panic-token legs only.
fn r2_range_scope(path: &str) -> bool {
    path.starts_with("serve/") || path == "checkpoint.rs"
}

/// Whether `code` contains a raw range-index expression like `x[a..b]`
/// (a value followed by brackets holding a top-level `..`). `get(a..b)`
/// is the sanctioned replacement. Array/vec literals and attributes do
/// not match (no value precedes their bracket).
fn has_range_index(code: &str) -> bool {
    let b = code.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'[' {
            let mut p = i;
            while p > 0 && b[p - 1] == b' ' {
                p -= 1;
            }
            let indexes_a_value =
                p > 0 && (is_ident_byte(b[p - 1]) || b[p - 1] == b')' || b[p - 1] == b']');
            if indexes_a_value {
                let mut depth = 1;
                let mut j = i + 1;
                while j < b.len() && depth > 0 {
                    match b[j] {
                        b'[' => depth += 1,
                        b']' => depth -= 1,
                        b'.' if depth == 1 && b.get(j + 1) == Some(&b'.') => return true,
                        _ => {}
                    }
                    j += 1;
                }
            }
        }
        i += 1;
    }
    false
}

/// R2: the serve worker loop, the queue, checkpoint decode and the
/// trainer's rank supervision must fail with typed errors, never panics —
/// a panicking worker strands waiters and a panicking rank looks exactly
/// like a crashed one to its peers. Deliberate panics (fault injection)
/// carry annotations.
pub fn r2_panic_safety(f: &SourceFile, out: &mut Vec<Finding>) {
    if !r2_in_scope(&f.rel_path) {
        return;
    }
    let range_scope = r2_range_scope(&f.rel_path);
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for tok in R2_DOT_TOKENS {
            if line.code.contains(tok) {
                out.push(finding(f, idx, "panic", format!("`{tok}` in a panic-safe path")));
            }
        }
        for tok in R2_MACRO_TOKENS {
            // Word-bounded so `my_panic!` style identifiers do not match.
            if has_word(&line.code, tok) {
                out.push(finding(f, idx, "panic", format!("`{tok}` in a panic-safe path")));
            }
        }
        if range_scope && has_range_index(&line.code) {
            out.push(finding(
                f,
                idx,
                "panic",
                "raw range index in a panic-safe path (use `.get(a..b)`)".to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// R3: collective safety
// ---------------------------------------------------------------------------

const COLLECTIVES: &[&str] =
    &[".allreduce_mean(", ".allreduce_sum(", ".broadcast(", ".barrier(", ".allgather_f64("];

/// R3: every `Comm` collective call must propagate or match its
/// `Result<_, CommError>`. Unwrapping turns a recoverable rank failure
/// into a panic (which peers then see as *another* rank failure), and
/// discarding it lets a rank continue on stale values after a failed
/// round. Applies to every file — collectives must be safe wherever they
/// are called from.
pub fn r3_collective_safety(f: &SourceFile, out: &mut Vec<Finding>) {
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let Some(tok) = COLLECTIVES.iter().find(|t| line.code.contains(**t)) else {
            continue;
        };
        // The call's statement may wrap; scan to the terminating `;`.
        let mut span = String::new();
        let mut j = idx;
        while j < f.lines.len() && j < idx + 5 {
            span.push_str(&f.lines[j].code);
            span.push(' ');
            if f.lines[j].code.trim_end().ends_with(';') {
                break;
            }
            j += 1;
        }
        let discarded = line.code.trim_start().starts_with("let _ =");
        let unwrapped =
            span.contains(".unwrap()") || span.contains(".expect(") || span.contains(".ok()");
        if unwrapped || discarded {
            let how = if discarded { "discarded" } else { "unwrapped" };
            let name = tok.trim_start_matches('.').trim_end_matches('(');
            out.push(finding(
                f,
                idx,
                "collective",
                format!("collective `{name}` result {how} instead of propagated"),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// R4: config coverage
// ---------------------------------------------------------------------------

/// R4: every `RunConfig` leaf field must appear either as a token of
/// `trajectory_fingerprint_resolved` or in the `FINGERPRINT_EXCLUDED`
/// table (with a reason) — never both, never neither. This turns the
/// "new knob silently skips fingerprinting" failure mode into a build
/// break: adding a field forces an explicit trajectory-relevance call.
pub fn r4_config_coverage(files: &[SourceFile], out: &mut Vec<Finding>) {
    let Some(cfg) = files.iter().find(|f| f.rel_path == "config.rs") else {
        return;
    };
    let Some(run_line) = find_code(cfg, "struct RunConfig") else {
        return;
    };
    let structs = parse_structs(cfg);
    let Some(run_fields) = structs.get("RunConfig") else {
        return;
    };
    let tokens = fingerprint_tokens(cfg);
    if tokens.is_empty() {
        out.push(finding(
            cfg,
            run_line,
            "config",
            "cannot locate `trajectory_fingerprint_resolved` format tokens".to_string(),
        ));
        return;
    }
    let excluded = excluded_entries(cfg);
    if excluded.is_empty() {
        out.push(finding(
            cfg,
            run_line,
            "config",
            "cannot locate the `FINGERPRINT_EXCLUDED` table".to_string(),
        ));
        return;
    }
    // Expand RunConfig one level: a field whose type is a struct defined
    // in config.rs contributes its leaves as `group.field`.
    let mut leaves: Vec<(String, usize)> = Vec::new();
    for (fname, ftype, fline) in run_fields {
        match structs.get(ftype.as_str()) {
            Some(sub) => {
                for (sname, _stype, sline) in sub {
                    leaves.push((format!("{fname}.{sname}"), *sline));
                }
            }
            None => leaves.push((fname.clone(), *fline)),
        }
    }
    for (leaf, line_idx) in &leaves {
        let last = leaf.rsplit('.').next().unwrap_or(leaf.as_str());
        let underscored = leaf.replace('.', "_");
        let in_fp = tokens.contains(&underscored) || tokens.contains(last);
        let in_ex = excluded.iter().any(|(p, _)| p == leaf);
        if in_fp && in_ex {
            out.push(finding(
                cfg,
                *line_idx,
                "config",
                format!("`{leaf}` is both fingerprinted and in FINGERPRINT_EXCLUDED"),
            ));
        } else if !in_fp && !in_ex {
            out.push(finding(
                cfg,
                *line_idx,
                "config",
                format!(
                    "`RunConfig` leaf `{leaf}` is in neither \
                     `trajectory_fingerprint_resolved` nor `FINGERPRINT_EXCLUDED`"
                ),
            ));
        }
    }
    for (path, line_idx) in &excluded {
        if !leaves.iter().any(|(l, _)| l == path) {
            out.push(finding(
                cfg,
                *line_idx,
                "config",
                format!("stale FINGERPRINT_EXCLUDED entry `{path}`: no such RunConfig field"),
            ));
        }
    }
}

/// 0-based line of the first non-test code line containing `needle`.
fn find_code(f: &SourceFile, needle: &str) -> Option<usize> {
    f.lines.iter().position(|l| !l.in_test && l.code.contains(needle))
}

/// Every `pub struct X { pub field: Type, ... }` in the file, with the
/// 0-based line of each field declaration.
fn parse_structs(f: &SourceFile) -> BTreeMap<String, Vec<(String, String, usize)>> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < f.lines.len() {
        let line = &f.lines[i];
        let name = if line.in_test { None } else { struct_decl_name(&line.code) };
        let Some(name) = name else {
            i += 1;
            continue;
        };
        let mut fields: Vec<(String, String, usize)> = Vec::new();
        let mut depth: i64 = line.code.chars().filter(|&c| c == '{').count() as i64
            - line.code.chars().filter(|&c| c == '}').count() as i64;
        let mut j = i + 1;
        while j < f.lines.len() && depth > 0 {
            let code = &f.lines[j].code;
            if depth == 1 {
                if let Some((fname, ftype)) = parse_field(code) {
                    fields.push((fname, ftype, j));
                }
            }
            for c in code.chars() {
                match c {
                    '{' => depth += 1,
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            j += 1;
        }
        out.insert(name, fields);
        i = j;
    }
    out
}

/// `Some(name)` for a `pub struct Name {` declaration line (unit and
/// tuple structs have no braced fields and are skipped).
fn struct_decl_name(code: &str) -> Option<String> {
    let t = code.trim_start();
    let rest = t.strip_prefix("pub struct ")?;
    if !code.contains('{') {
        return None;
    }
    let name: String =
        rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// `Some((name, type))` for a `pub name: Type,` field line.
fn parse_field(code: &str) -> Option<(String, String)> {
    let t = code.trim();
    let rest = t.strip_prefix("pub ")?;
    let colon = rest.find(':')?;
    let name = rest[..colon].trim();
    if name.is_empty() || !name.bytes().all(is_ident_byte) {
        return None;
    }
    let ftype = rest[colon + 1..].trim().trim_end_matches(',').trim();
    Some((name.to_string(), ftype.to_string()))
}

/// The `name={...}` tokens of the fingerprint format string, read from the
/// RAW lines of `fn trajectory_fingerprint_resolved` (the tokens live
/// inside a string literal, which the code view deliberately blanks).
fn fingerprint_tokens(f: &SourceFile) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let Some(start) = find_code(f, "fn trajectory_fingerprint_resolved") else {
        return out;
    };
    let mut depth: i64 = 0;
    let mut opened = false;
    let mut j = start;
    while j < f.lines.len() {
        if let Some(raw) = f.raw.get(j) {
            collect_eq_brace_idents(raw, &mut out);
        }
        for c in f.lines[j].code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            break;
        }
        j += 1;
    }
    out
}

/// Collect each `ident={` occurrence in `raw` into `out`.
fn collect_eq_brace_idents(raw: &str, out: &mut BTreeSet<String>) {
    let b = raw.as_bytes();
    for i in 0..b.len() {
        if b[i] != b'=' || b.get(i + 1) != Some(&b'{') {
            continue;
        }
        let mut s = i;
        while s > 0 && is_ident_byte(b[s - 1]) {
            s -= 1;
        }
        if s < i {
            if let Some(tok) = raw.get(s..i) {
                out.insert(tok.to_string());
            }
        }
    }
}

/// The `("field.path", "reason")` entries of `FINGERPRINT_EXCLUDED`, read
/// from RAW lines (string literals again), with each entry's 0-based line.
fn excluded_entries(f: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let Some(start) = find_code(f, "FINGERPRINT_EXCLUDED") else {
        return out;
    };
    let mut j = start;
    while j < f.lines.len() && j < start + 64 {
        if let Some(raw) = f.raw.get(j) {
            if let Some(entry) = first_quoted(raw) {
                out.push((entry, j));
            }
        }
        if f.lines[j].code.contains("];") {
            break;
        }
        j += 1;
    }
    out
}

/// The first `"..."` substring of `raw`, if any.
fn first_quoted(raw: &str) -> Option<String> {
    let open = raw.find('"')?;
    let rest = raw.get(open + 1..)?;
    let close = rest.find('"')?;
    rest.get(..close).map(str::to_string)
}

// ---------------------------------------------------------------------------
// R5: env-var registry
// ---------------------------------------------------------------------------

/// R5: every `HYDRA_MTP_*` env read must appear in
/// `lint/env_registry.rs`, and every registry entry must still have a
/// read site (checked only on full-tree scans — fixture sets cannot see
/// the whole tree). Reads are found on RAW lines: the variable name is a
/// string literal, which the code view blanks.
pub fn r5_env_registry(files: &[SourceFile], registry: &[EnvVar], out: &mut Vec<Finding>) {
    let mut reads: BTreeSet<String> = BTreeSet::new();
    for f in files {
        for (idx, raw) in f.raw.iter().enumerate() {
            if f.lines.get(idx).is_some_and(|l| l.in_test) {
                continue;
            }
            for name in env_reads_in(raw) {
                let registered = registry.iter().any(|v| v.name == name);
                if !registered {
                    out.push(finding(
                        f,
                        idx,
                        "env",
                        format!("`{name}` is read here but missing from lint/env_registry.rs"),
                    ));
                }
                reads.insert(name);
            }
        }
    }
    if files.iter().any(|f| f.rel_path == "lint/env_registry.rs") {
        for v in registry {
            if !reads.contains(v.name) {
                out.push(Finding {
                    rule: "env",
                    file: "lint/env_registry.rs".to_string(),
                    line: 1,
                    message: format!("stale registry entry `{}`: no read site in the tree", v.name),
                    allowed_reason: None,
                    allow_decl_line: None,
                });
            }
        }
    }
}

/// `HYDRA_MTP_*` names read via `env::var` / `env::var_os` on this raw
/// line. The needle is the call syntax, not the prefix alone, so prefix
/// constants in this module do not read as env accesses.
fn env_reads_in(raw: &str) -> Vec<String> {
    let mut out = Vec::new();
    for needle in ["var(", "var_os("] {
        let mut from = 0;
        while let Some(p) = raw.get(from..).and_then(|s| s.find(needle)) {
            let at = from + p + needle.len();
            from = at;
            let Some(rest) = raw.get(at..) else {
                break;
            };
            let rest = rest.trim_start();
            let Some(arg) = rest.strip_prefix('"') else {
                continue;
            };
            let name: String = arg
                .chars()
                .take_while(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || *c == '_')
                .collect();
            if name.starts_with("HYDRA_MTP_") {
                out.push(name);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// annotation hygiene
// ---------------------------------------------------------------------------

/// Violations for malformed annotations: unknown rule names, missing
/// reasons, and annotations that suppressed nothing (`findings` is the
/// output of the rules above; a consumed annotation is identified by its
/// declaration line).
pub fn check_annotations(files: &[SourceFile], findings: &[Finding], out: &mut Vec<Finding>) {
    let mut used: BTreeSet<(String, usize)> = BTreeSet::new();
    for fd in findings {
        if let Some(decl) = fd.allow_decl_line {
            used.insert((fd.file.clone(), decl));
        }
    }
    for f in files {
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        for line in &f.lines {
            if line.in_test {
                continue;
            }
            for a in &line.allows {
                if !seen.insert(a.decl_line) {
                    continue;
                }
                if !ALLOWABLE_RULES.contains(&a.rule.as_str()) {
                    out.push(Finding {
                        rule: "annotation",
                        file: f.rel_path.clone(),
                        line: a.decl_line + 1,
                        message: format!("unknown rule `{}` in lint:allow annotation", a.rule),
                        allowed_reason: None,
                        allow_decl_line: None,
                    });
                    continue;
                }
                if a.reason.is_empty() {
                    out.push(Finding {
                        rule: "annotation",
                        file: f.rel_path.clone(),
                        line: a.decl_line + 1,
                        message: "lint:allow annotation without a reason".to_string(),
                        allowed_reason: None,
                        allow_decl_line: None,
                    });
                    continue;
                }
                if !used.contains(&(f.rel_path.clone(), a.decl_line)) {
                    out.push(Finding {
                        rule: "annotation",
                        file: f.rel_path.clone(),
                        line: a.decl_line + 1,
                        message: format!(
                            "lint:allow({}) annotation suppresses nothing here",
                            a.rule
                        ),
                        allowed_reason: None,
                        allow_decl_line: None,
                    });
                }
            }
        }
    }
}
