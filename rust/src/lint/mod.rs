//! hydra-lint: the crate's zero-dependency static invariant checker.
//!
//! Clippy enforces Rust idioms; this pass enforces *project* invariants
//! clippy cannot express — the properties the paper's multi-rank training
//! guarantees rest on. Five rules (see [`rules`]):
//!
//! - **R1 `nondeterministic`** — no `HashMap`/`HashSet` and no
//!   `Instant::now` in the determinism-critical modules (`model/egnn.rs`,
//!   `model/kernels.rs`, `comm/`, `checkpoint.rs`, `data/graph.rs`).
//!   Arbitrary iteration order or wall-clock-derived ordering there breaks
//!   the bit-reproducibility the resume/recovery proofs depend on.
//! - **R2 `panic`** — no `unwrap`/`expect`/panic macros (and, where
//!   untrusted lengths flow, no raw range indexing) in the serve worker
//!   loop, the queue, checkpoint decode, and the trainer's rank
//!   supervision. A panic there strands waiters or masquerades as a rank
//!   failure; typed errors recover, panics don't.
//! - **R3 `collective`** — every `Comm` collective result is propagated
//!   or matched, never unwrapped or discarded, in every file.
//! - **R4 `config`** — every `RunConfig` leaf is named either in
//!   `trajectory_fingerprint_resolved` or in `FINGERPRINT_EXCLUDED`;
//!   adding a field forces an explicit trajectory-relevance decision.
//! - **R5 `env`** — every `HYDRA_MTP_*` read appears in
//!   [`env_registry::REGISTRY`], which also renders the CLI `--help`.
//!
//! Justified exceptions carry `lint:allow` annotations (grammar in
//! [`scan`]); unknown rules, missing reasons and annotations that
//! suppress nothing are themselves violations. The `hydra_lint` binary
//! walks `rust/src/**` (its own sources included), prints `file:line`
//! diagnostics, writes a machine-readable `LINT_report.json`, and exits
//! nonzero on any violation — CI runs it as a blocking job.

pub mod env_registry;
pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One rule hit. `allowed_reason` is `Some` when a `lint:allow`
/// annotation covers the site (the hit is then informational).
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub message: String,
    pub allowed_reason: Option<String>,
    /// 0-based declaration line of the consumed annotation (for the
    /// stale-annotation check).
    pub allow_decl_line: Option<usize>,
}

impl Finding {
    pub fn is_violation(&self) -> bool {
        self.allowed_reason.is_none()
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("rule", Json::str(self.rule)),
            ("file", Json::str(self.file.clone())),
            ("line", Json::from(self.line)),
            ("message", Json::str(self.message.clone())),
        ];
        if let Some(reason) = &self.allowed_reason {
            pairs.push(("allowed_reason", Json::str(reason.clone())));
        }
        Json::obj(pairs)
    }
}

/// Outcome of a lint run: violations fail the build, allowed sites are
/// the audited exception surface.
pub struct Report {
    pub root: String,
    pub files_scanned: usize,
    pub violations: Vec<Finding>,
    pub allowed: Vec<Finding>,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// The `LINT_report.json` payload (deterministic key order via the
    /// crate's BTreeMap-backed [`Json`]).
    pub fn to_json(&self) -> Json {
        let mut by_rule: std::collections::BTreeMap<&str, (i64, i64)> =
            std::collections::BTreeMap::new();
        for f in &self.violations {
            by_rule.entry(f.rule).or_insert((0, 0)).0 += 1;
        }
        for f in &self.allowed {
            by_rule.entry(f.rule).or_insert((0, 0)).1 += 1;
        }
        let counts = Json::obj(
            by_rule
                .iter()
                .map(|(rule, (v, a))| {
                    let c = Json::obj(vec![
                        ("violations", Json::from(*v)),
                        ("allowed", Json::from(*a)),
                    ]);
                    (*rule, c)
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::str("hydra-lint-report/v1")),
            ("root", Json::str(self.root.clone())),
            ("files_scanned", Json::from(self.files_scanned)),
            ("clean", Json::from(self.clean())),
            ("counts", counts),
            ("violations", Json::Array(self.violations.iter().map(Finding::to_json).collect())),
            ("allowed", Json::Array(self.allowed.iter().map(Finding::to_json).collect())),
        ])
    }

    /// Human diagnostics: one `file:line` line per violation, then a
    /// summary naming the annotated-exception count per rule.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.violations {
            out.push_str(&format!("error[{}] {}:{}: {}\n", f.rule, f.file, f.line, f.message));
        }
        let mut allowed_rules: std::collections::BTreeMap<&str, usize> =
            std::collections::BTreeMap::new();
        for f in &self.allowed {
            *allowed_rules.entry(f.rule).or_insert(0) += 1;
        }
        let allowed_desc = if allowed_rules.is_empty() {
            "none".to_string()
        } else {
            allowed_rules
                .iter()
                .map(|(r, n)| format!("{r}={n}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        out.push_str(&format!(
            "hydra-lint: {} files scanned, {} violation(s), annotated allowances: {}\n",
            self.files_scanned,
            self.violations.len(),
            allowed_desc
        ));
        out
    }
}

/// Run every rule over an in-memory file set (the integration tests feed
/// fixture snippets through this same path the binary uses).
pub fn check_files(files: &[scan::SourceFile]) -> Vec<Finding> {
    let mut findings: Vec<Finding> = Vec::new();
    for f in files {
        rules::r1_determinism(f, &mut findings);
        rules::r2_panic_safety(f, &mut findings);
        rules::r3_collective_safety(f, &mut findings);
    }
    rules::r4_config_coverage(files, &mut findings);
    rules::r5_env_registry(files, env_registry::REGISTRY, &mut findings);
    let mut hygiene: Vec<Finding> = Vec::new();
    rules::check_annotations(files, &findings, &mut hygiene);
    findings.extend(hygiene);
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings
}

/// Lint every `.rs` file under `root` (deterministic sorted order).
pub fn run(root: &Path) -> anyhow::Result<Report> {
    let mut rel_paths: Vec<String> = Vec::new();
    walk(root, Path::new(""), &mut rel_paths)
        .map_err(|e| anyhow::anyhow!("cannot walk {}: {e}", root.display()))?;
    rel_paths.sort();
    let mut files: Vec<scan::SourceFile> = Vec::with_capacity(rel_paths.len());
    for rel in &rel_paths {
        let full = root.join(rel);
        let text = std::fs::read_to_string(&full)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", full.display()))?;
        files.push(scan::SourceFile::parse(rel, &text));
    }
    let findings = check_files(&files);
    let (allowed, violations): (Vec<Finding>, Vec<Finding>) =
        findings.into_iter().partition(|f| f.allowed_reason.is_some());
    Ok(Report {
        root: root.display().to_string(),
        files_scanned: files.len(),
        violations,
        allowed,
    })
}

fn walk(root: &Path, rel: &Path, out: &mut Vec<String>) -> std::io::Result<()> {
    let dir = root.join(rel);
    for entry in std::fs::read_dir(&dir)? {
        let entry = entry?;
        let name: PathBuf = entry.file_name().into();
        let child = rel.join(&name);
        let ft = entry.file_type()?;
        if ft.is_dir() {
            walk(root, &child, out)?;
        } else if name.to_string_lossy().ends_with(".rs") {
            // `/`-separated rel paths so rule scoping is platform-stable.
            let parts: Vec<String> = child
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect();
            out.push(parts.join("/"));
        }
    }
    Ok(())
}
