//! Source scanner for the hydra-lint rules: turns one Rust source file
//! into per-line records carrying (a) a *code view* — comments stripped
//! and string/char-literal contents blanked, so token matching cannot be
//! fooled by doc prose or by rule names spelled inside literals — (b) a
//! `#[cfg(test)]` membership flag (rules exempt test code, where `unwrap`
//! on a just-constructed value is idiomatic), and (c) the annotations
//! attached to each line.
//!
//! This is a line/token scanner, not a parser: it understands exactly the
//! lexical structure the rules need — line and (nested) block comments,
//! string, raw-string and char literals, brace nesting for test modules —
//! and nothing more, in the spirit of the crate's vendored-deps policy.
//!
//! # Annotation grammar
//!
//! A suppression is a line comment whose content *begins with* the marker
//! (so prose in doc comments that merely mentions the grammar never
//! parses as one):
//!
//! ```text
//! // lint:allow(<rule>): <reason>
//! ```
//!
//! On its own line it covers the statement that follows (through the
//! first line ending in `;`, `{` or `}`, so a wrapped statement is fully
//! covered); as a trailing comment it covers its own line. The `<reason>`
//! is mandatory — an annotation with no justification is itself a lint
//! violation, as is one that suppresses nothing.

/// One parsed `lint:allow` annotation.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Rule name inside the parens (validated by the rules pass).
    pub rule: String,
    /// Justification after the colon (empty when omitted — a violation).
    pub reason: String,
    /// 0-based line the annotation comment sits on (its identity for the
    /// stale-annotation check).
    pub decl_line: usize,
}

/// One source line, post-lex.
pub struct Line {
    /// The code view: comments stripped, literal contents blanked.
    pub code: String,
    /// Inside a `#[cfg(test)]` item (attribute line included).
    pub in_test: bool,
    /// Annotations covering this line (see the module docs for coverage).
    pub allows: Vec<Allow>,
}

/// A scanned file: raw lines (for the table-driven rules that must read
/// literal contents) plus the lexed per-line records.
pub struct SourceFile {
    /// Path relative to the scan root, `/`-separated (e.g. `serve/queue.rs`).
    pub rel_path: String,
    /// Original text, split into lines.
    pub raw: Vec<String>,
    pub lines: Vec<Line>,
}

impl SourceFile {
    pub fn parse(rel_path: &str, text: &str) -> SourceFile {
        let views = split_views(text);
        let mut lines: Vec<Line> = Vec::with_capacity(views.len());
        let mut raw_allows: Vec<Option<Allow>> = Vec::with_capacity(views.len());
        for (idx, (code, comment)) in views.into_iter().enumerate() {
            raw_allows.push(parse_allow(&comment, idx));
            lines.push(Line { code, in_test: false, allows: Vec::new() });
        }
        mark_tests(&mut lines);
        attach_allows(&mut lines, raw_allows);
        // `split('\n')` (not `lines()`) so `raw` and `lines` stay the same
        // length even when the file ends with a newline.
        let raw = text.split('\n').map(str::to_string).collect();
        SourceFile { rel_path: rel_path.to_string(), raw, lines }
    }

    /// The first annotation for `rule` covering 0-based line `idx`, if any.
    pub fn allow_for(&self, idx: usize, rule: &str) -> Option<&Allow> {
        self.lines.get(idx).and_then(|l| l.allows.iter().find(|a| a.rule == rule))
    }
}

#[derive(Clone, Copy)]
enum State {
    Normal,
    LineComment,
    /// Nesting depth (Rust block comments nest).
    Block(u32),
    Str,
    /// Number of `#`s in the opening `r#*"` delimiter.
    Raw(usize),
}

/// Split `text` into per-line `(code, comment)` views. Literal contents
/// are blanked from the code view (delimiters kept); comment text is
/// collected separately so annotations can be parsed from it. Newlines
/// inside multi-line strings and block comments are preserved as line
/// breaks so line numbers stay aligned with the original file.
fn split_views(text: &str) -> Vec<(String, String)> {
    let chars: Vec<char> = text.chars().collect();
    let mut out: Vec<(String, String)> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Normal;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            out.push((std::mem::take(&mut code), std::mem::take(&mut comment)));
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if c == 'r' {
                    if let Some(hashes) = raw_string_hashes(&chars, i) {
                        code.push('r');
                        code.push('"');
                        state = State::Raw(hashes);
                        i += 2 + hashes;
                    } else {
                        code.push('r');
                        i += 1;
                    }
                } else if c == '\'' {
                    if let Some(len) = char_literal_len(&chars, i) {
                        code.push('\'');
                        code.push('\'');
                        i += len;
                    } else {
                        // A lifetime tick; keep it so generics stay intact.
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::Block(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if depth == 1 { State::Normal } else { State::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Skip the escaped char — unless it is a newline
                    // (line-continuation escape), which must reach the
                    // `'\n'` handler above to keep line counts right.
                    if chars.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    code.push('"');
                    state = State::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::Raw(hashes) => {
                if c == '"' && (0..hashes).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                    code.push('"');
                    state = State::Normal;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    out.push((code, comment));
    out
}

/// `Some(hash_count)` when `chars[i]` (an `r`) opens a raw string literal
/// (`r"`, `r#"`, ...). An identifier char before the `r` means it is the
/// tail of an identifier (`var`), and `r#ident` raw identifiers have no
/// quote after the hashes; both return `None`.
fn raw_string_hashes(chars: &[char], i: usize) -> Option<usize> {
    if i > 0 {
        let prev = chars[i - 1];
        if prev.is_alphanumeric() || prev == '_' {
            return None;
        }
    }
    let mut hashes = 0;
    let mut j = i + 1;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

/// `Some(total_len)` when `chars[i]` (a `'`) opens a char literal;
/// `None` for a lifetime tick. Escaped literals (`'\n'`, `'\u{1F600}'`)
/// are found by scanning a bounded window for the closing quote.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1) {
        Some('\\') => {
            let mut j = i + 3;
            while j < chars.len() && j <= i + 12 {
                if chars[j] == '\'' {
                    return Some(j - i + 1);
                }
                j += 1;
            }
            None
        }
        Some(_) => {
            if chars.get(i + 2) == Some(&'\'') {
                Some(3)
            } else {
                None
            }
        }
        None => None,
    }
}

/// Mark every line belonging to a `#[cfg(test)]` item. The attribute line
/// is found in the code view; the item's extent is brace-counted from its
/// first `{`. An attribute on a braceless item (`#[cfg(test)] use ...;`)
/// ends at the first `;` before any brace opens.
fn mark_tests(lines: &mut [Line]) {
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            lines[j].in_test = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            if !opened && lines[j].code.trim_end().ends_with(';') {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
}

/// Parse an annotation from one line's comment text. Only a comment whose
/// content *begins with* the marker counts (see the module docs), so doc
/// prose describing the grammar never parses as a suppression.
fn parse_allow(comment: &str, decl_line: usize) -> Option<Allow> {
    let t = comment.trim_start();
    let rest = t.strip_prefix("lint:allow(")?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let tail = &rest[close + 1..];
    let reason = match tail.strip_prefix(':') {
        Some(r) => r.trim().to_string(),
        None => String::new(),
    };
    Some(Allow { rule, reason, decl_line })
}

/// Attach each parsed annotation to the lines it covers: its own line
/// when it trails code, otherwise the next statement (through the first
/// line ending in `;`, `{` or `}`, capped at 8 lines).
fn attach_allows(lines: &mut [Line], raw_allows: Vec<Option<Allow>>) {
    for (idx, allow) in raw_allows.into_iter().enumerate() {
        let Some(allow) = allow else {
            continue;
        };
        if !lines[idx].code.trim().is_empty() {
            lines[idx].allows.push(allow);
            continue;
        }
        let mut j = idx + 1;
        while j < lines.len() && lines[j].code.trim().is_empty() {
            j += 1;
        }
        let start = j;
        while j < lines.len() && j - start < 8 {
            lines[j].allows.push(allow.clone());
            let t = lines[j].code.trim_end();
            if t.ends_with(';') || t.ends_with('{') || t.ends_with('}') {
                break;
            }
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_leave_the_code_view() {
        let src = "let x = \"HashMap\"; // HashMap here too\nlet y = 1;\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(!f.lines[0].code.contains("HashMap"));
        assert!(f.lines[0].code.contains("let x"));
        assert_eq!(f.lines[1].code, "let y = 1;");
    }

    #[test]
    fn raw_strings_and_char_literals_blank_out() {
        let src = "let r = r#\"panic!(inside)\"#;\nlet c = 'x';\nlet lt: &'static str = \"\";\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(!f.lines[0].code.contains("panic"));
        assert!(f.lines[1].code.contains("''"));
        assert!(f.lines[2].code.contains("'static"));
    }

    #[test]
    fn multiline_string_with_continuation_keeps_line_numbers() {
        let src = "let s = \"abc\\\n   def\";\nlet z = 9;\n";
        let f = SourceFile::parse("a.rs", src);
        assert_eq!(f.lines.len(), 4); // 3 lines + trailing empty
        assert_eq!(f.lines[2].code, "let z = 9;");
    }

    #[test]
    fn cfg_test_blocks_are_marked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn standalone_annotation_covers_the_next_statement() {
        let src = "// lint:allow(panic): fixture reason\nlet x = foo\n    .bar();\nlet y = 1;\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(f.allow_for(1, "panic").is_some());
        assert!(f.allow_for(2, "panic").is_some());
        assert!(f.allow_for(3, "panic").is_none());
        assert_eq!(f.allow_for(1, "panic").map(|a| a.decl_line), Some(0));
    }

    #[test]
    fn trailing_annotation_covers_its_own_line_only() {
        let src = "let x = 1; // lint:allow(panic): here\nlet y = 2;\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(f.allow_for(0, "panic").is_some());
        assert!(f.allow_for(1, "panic").is_none());
    }

    #[test]
    fn doc_prose_mentioning_the_grammar_is_not_an_annotation() {
        let src = "/// write `lint:allow(panic): why` above the site\nfn f() {}\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(f.allow_for(1, "panic").is_none());
    }
}
