//! Central registry of every `HYDRA_MTP_*` environment variable the crate
//! reads. hydra-lint rule R5 enforces it in both directions: an env read
//! that is not listed here fails the lint, and an entry that is no longer
//! read anywhere fails it too (stale docs are wrong docs). The CLI's
//! `--help` renders [`help_text`], so the documented surface can never
//! drift from the code.

/// One documented environment variable.
pub struct EnvVar {
    pub name: &'static str,
    /// Effect when set (one line; rendered in `--help`).
    pub summary: &'static str,
    /// Behavior when unset.
    pub unset: &'static str,
}

/// Every `HYDRA_MTP_*` variable the crate reads, alphabetically.
pub const REGISTRY: &[EnvVar] = &[
    EnvVar {
        name: "HYDRA_MTP_BACKEND",
        summary: "execution backend override: native | pjrt | auto \
                  (an invalid value warns and keeps auto)",
        unset: "auto — pjrt when artifacts + the feature are available, else native",
    },
    EnvVar {
        name: "HYDRA_MTP_FAULTS",
        summary: "fault-injection spec overriding the configured plan, e.g. \
                  rank-panic@rank=1,epoch=2,step=0;stall@rank=0,epoch=0,step=1,ms=200",
        unset: "faults come from --faults / RunConfig.fault.spec (default: none)",
    },
    EnvVar {
        name: "HYDRA_MTP_OVERLAP",
        summary: "overlapped bucketed gradient reduction override: 1|true|on or \
                  0|false|off (an invalid value warns and keeps the config; \
                  reduced values are bit-identical either way)",
        unset: "the configured ParallelConfig.overlap flag (default: off)",
    },
    EnvVar {
        name: "HYDRA_MTP_PRECISION",
        summary: "native-backend precision override: f64 | mixed-f32 \
                  (an invalid value warns and is ignored)",
        unset: "the configured precision (default f64, the gradcheck oracle)",
    },
    EnvVar {
        name: "HYDRA_MTP_THREADS",
        summary: "kernel worker cap, read once per process; 0 means serial, \
                  large values are clamped",
        unset: "the default thread cap (8)",
    },
];

/// The `--help` Environment section, rendered from [`REGISTRY`].
pub fn help_text() -> String {
    let mut out = String::from("Environment variables:\n");
    for v in REGISTRY {
        out.push_str(&format!("  {}\n", v.name));
        out.push_str(&format!("      {}\n", v.summary));
        out.push_str(&format!("      unset: {}\n", v.unset));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_and_prefixed() {
        for w in REGISTRY.windows(2) {
            assert!(w[0].name < w[1].name, "registry must stay alphabetical");
        }
        for v in REGISTRY {
            assert!(v.name.starts_with("HYDRA_MTP_"), "bad prefix: {}", v.name);
            assert!(!v.summary.is_empty() && !v.unset.is_empty());
        }
    }

    #[test]
    fn help_text_names_every_variable() {
        let h = help_text();
        for v in REGISTRY {
            assert!(h.contains(v.name));
        }
    }
}
