//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse a raw argv slice (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.flags.insert(body.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(body.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list: `--sizes 40,80,160`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key} expects integers, got '{s}'"))
                })
                .collect(),
        }
    }

    pub fn str_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Reject unknown / misspelled `--flags`: error listing the valid flags
    /// for `context` (a subcommand name) when any parsed flag is not in
    /// `allowed`. Without this, typos like `--replica 4` were silently
    /// ignored and defaults won.
    pub fn ensure_known(&self, context: &str, allowed: &[&str]) -> anyhow::Result<()> {
        let unknown: Vec<String> = self
            .flags
            .keys()
            .filter(|k| !allowed.contains(&k.as_str()))
            .map(|k| format!("--{k}"))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        let mut valid: Vec<String> = allowed.iter().map(|f| format!("--{f}")).collect();
        valid.sort();
        anyhow::bail!(
            "unknown flag{} {} for `{context}`; valid flags: {}",
            if unknown.len() == 1 { "" } else { "s" },
            unknown.join(", "),
            if valid.is_empty() { "(none)".to_string() } else { valid.join(", ") }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_forms() {
        let a = parse(&["train", "--epochs", "5", "--lr=0.01", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize("epochs", 0), 5);
        assert_eq!(a.f64("lr", 0.0), 0.01);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.str("name", "x"), "x");
        assert!(!a.bool("flag"));
    }

    #[test]
    fn lists() {
        let a = parse(&["--gpus", "40,80,160"]);
        assert_eq!(a.usize_list("gpus", &[]), vec![40, 80, 160]);
        assert_eq!(a.usize_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.bool("a"));
        assert_eq!(a.str("b", ""), "x");
    }

    #[test]
    fn ensure_known_accepts_allowed_flags() {
        let a = parse(&["train", "--epochs", "5", "--replicas=2"]);
        a.ensure_known("train", &["epochs", "replicas", "mode"]).unwrap();
        a.ensure_known("train", &["epochs", "replicas"]).unwrap();
    }

    #[test]
    fn ensure_known_rejects_typos_listing_valid_flags() {
        // The motivating bug: `--replica 4` (singular) used to be silently
        // ignored, so the run proceeded with the default replica count.
        let a = parse(&["train", "--replica", "4"]);
        let err = a.ensure_known("train", &["epochs", "replicas"]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--replica"), "{msg}");
        assert!(msg.contains("`train`"), "{msg}");
        assert!(msg.contains("--replicas"), "{msg}");
        assert!(msg.contains("--epochs"), "{msg}");
    }

    #[test]
    fn ensure_known_lists_every_unknown_flag() {
        let a = parse(&["--foo", "1", "--bar=2", "--ok"]);
        let err = a.ensure_known("cmd", &["ok"]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--foo") && msg.contains("--bar"), "{msg}");
        assert!(msg.contains("flags"), "plural form: {msg}");
    }
}
