//! Mini property-based testing harness.
//!
//! `proptest` is not available in the offline registry, so this provides the
//! subset we need: run a property over many seeded random cases and report
//! the first failing seed (re-runnable deterministically). Shrinking is
//! replaced by printing the seed + case debug representation, which is
//! sufficient because every generator here is a pure function of the seed.

use super::rng::Rng;

/// Mix a case index into a well-spread RNG seed.
#[inline]
pub fn case_seed(seed: u64) -> u64 {
    seed.wrapping_mul(0x9e3779b97f4a7c15) ^ 0x5851f42d4c957f2d
}

/// Run `prop` over `cases` seeded inputs produced by `gen`. Panics with the
/// offending seed on the first failure so the case can be replayed exactly.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for seed in 0..cases {
        let mut rng = Rng::new(case_seed(seed));
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!("property '{name}' failed at seed {seed}:\n  {msg}\n  case: {case:?}");
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn check_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("u64 roundtrip", 50, |r| r.next_u64(), |&x| {
            check(x == x, "reflexive")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        forall("always fails", 5, |r| r.below(10), |_| Err("nope".to_string()));
    }

    #[test]
    fn check_close_relative() {
        assert!(check_close(1000.0, 1000.5, 1e-3, "x").is_ok());
        assert!(check_close(1.0, 2.0, 1e-3, "x").is_err());
    }
}
