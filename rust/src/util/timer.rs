//! Micro-benchmark timing helpers (criterion is unavailable offline).
//!
//! `bench()` warms up, runs timed iterations until a wall-clock budget is
//! spent, and reports mean / p50 / p95 / min in a stable text format that the
//! bench binaries print and EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<6} mean={:>12?} p50={:>12?} p95={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Machine-readable form for `BENCH_*.json` artifacts (EXPERIMENTS.md
    /// §Perf): op name, ns/iter, throughput.
    pub fn to_json(&self) -> Json {
        let ns = self.mean.as_secs_f64() * 1e9;
        Json::obj(vec![
            ("op", Json::str(self.name.clone())),
            ("iters", Json::Int(self.iters as i64)),
            ("ns_per_iter", Json::Float(ns)),
            ("p50_ns", Json::Float(self.p50.as_secs_f64() * 1e9)),
            ("p95_ns", Json::Float(self.p95.as_secs_f64() * 1e9)),
            ("min_ns", Json::Float(self.min.as_secs_f64() * 1e9)),
            ("throughput_per_sec", Json::Float(if ns > 0.0 { 1e9 / ns } else { 0.0 })),
        ])
    }
}

/// Write a bench suite's stats as a machine-readable JSON artifact (e.g.
/// `BENCH_hot_paths.json`). CI uploads the file; EXPERIMENTS.md §Perf
/// tracks the trajectory across PRs.
///
/// Writes to `<path>.tmp` and renames into place, so a crash mid-write
/// never leaves a truncated file where CI expects valid JSON.
pub fn write_bench_json(
    path: impl AsRef<std::path::Path>,
    suite: &str,
    stats: &[BenchStats],
) -> std::io::Result<()> {
    let path = path.as_ref();
    let doc = Json::obj(vec![
        ("suite", Json::str(suite)),
        ("results", Json::Array(stats.iter().map(BenchStats::to_json).collect())),
    ]);
    let tmp = {
        let mut os = path.as_os_str().to_os_string();
        os.push(".tmp");
        std::path::PathBuf::from(os)
    };
    std::fs::write(&tmp, format!("{doc}\n"))?;
    std::fs::rename(&tmp, path)
}

/// Benchmark `f`, spending roughly `budget` of wall clock after `warmup`
/// untimed iterations. Returns per-iteration statistics.
pub fn bench(name: &str, warmup: usize, budget: Duration, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    stats_from(name, samples)
}

/// Benchmark with a fixed iteration count (for expensive end-to-end cases).
pub fn bench_n(name: &str, iters: usize, mut f: impl FnMut()) -> BenchStats {
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    stats_from(name, samples)
}

fn stats_from(name: &str, mut samples: Vec<Duration>) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let n = samples.len();
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    }
}

/// Simple scoped phase timer used by the trainer's metrics.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    pub total: Duration,
    pub count: usize,
}

impl PhaseTimer {
    pub fn record(&mut self, d: Duration) {
        self.total += d;
        self.count += 1;
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_n_counts_iters() {
        let s = bench_n("noop", 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 10);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn bench_respects_minimum_samples() {
        let s = bench("tiny", 1, Duration::from_millis(1), || {
            std::hint::black_box(2 * 2);
        });
        assert!(s.iters >= 5);
    }

    #[test]
    fn bench_json_roundtrips_and_has_the_schema() {
        let s = bench_n("op_a", 5, || {
            std::hint::black_box(1 + 1);
        });
        let path = std::env::temp_dir()
            .join(format!("hydra_bench_json_{}.json", std::process::id()));
        write_bench_json(&path, "unit", &[s]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("suite").as_str(), Some("unit"));
        let r = j.get("results").idx(0);
        assert_eq!(r.get("op").as_str(), Some("op_a"));
        assert_eq!(r.get("iters").as_i64(), Some(5));
        assert!(r.get("ns_per_iter").as_f64().unwrap() >= 0.0);
        assert!(r.get("throughput_per_sec").as_f64().is_some());
    }

    #[test]
    fn phase_timer_mean() {
        let mut t = PhaseTimer::default();
        t.record(Duration::from_millis(2));
        t.record(Duration::from_millis(4));
        assert_eq!(t.mean(), Duration::from_millis(3));
    }
}
