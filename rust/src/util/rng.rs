//! Deterministic RNG (SplitMix64 core) with the distributions the data
//! generators and parameter initializers need. No external crates are
//! available offline, and determinism across runs matters more than
//! cryptographic quality here.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and — unlike
/// thread_rng — reproducible so dataset generation is a pure function of the
/// seed recorded in EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller sample.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15), spare_normal: None }
    }

    /// Derive an independent stream (for per-rank / per-dataset seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xff51afd7ed558ccd);
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with all-zero weights");
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Choose k distinct indices from 0..n (partial Fisher-Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Random unit vector in R^3 (uniform on the sphere).
    pub fn unit3(&mut self) -> [f64; 3] {
        loop {
            let x = self.range(-1.0, 1.0);
            let y = self.range(-1.0, 1.0);
            let z = self.range(-1.0, 1.0);
            let n2 = x * x + y * y + z * z;
            if n2 > 1e-12 && n2 <= 1.0 {
                let n = n2.sqrt();
                return [x / n, y / n, z / n];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_is_unbiased_at_edges() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.below(3)] += 1;
        }
        for c in counts {
            assert!(c > 800, "{counts:?}");
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(9);
        let picks = r.choose_k(10, 5);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
        assert!(picks.iter().all(|&i| i < 10));
    }

    #[test]
    fn unit3_is_unit() {
        let mut r = Rng::new(2);
        for _ in 0..100 {
            let [x, y, z] = r.unit3();
            assert!(((x * x + y * y + z * z).sqrt() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(4);
        let mut heavy = 0;
        for _ in 0..1000 {
            if r.weighted(&[1.0, 9.0]) == 1 {
                heavy += 1;
            }
        }
        assert!(heavy > 800, "{heavy}");
    }
}
