//! Minimal JSON parser/serializer (no external deps are available offline).
//!
//! Covers the full JSON grammar needed by `artifacts/manifest.json` and the
//! run-config files: objects, arrays, strings (with escapes), numbers, bools,
//! null. Numbers are kept as f64 with an i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so iteration order is
/// deterministic (matches python's `sort_keys=True` output).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_object().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_array().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Int(v as i64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Float(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Array(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>().map(Json::Float).map_err(|_| self.err("bad number"))
        } else {
            text.parse::<i64>().map(Json::Int).map_err(|_| self.err("bad number"))
        }
    }
}

// ---------------------------------------------------------------------------
// serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(i) => write!(f, "{i}"),
            Json::Float(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        write!(f, "{:.1}", x)
                    } else {
                        write!(f, "{x}")
                    }
                } else {
                    write!(f, "null") // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Array(items) => {
                write!(f, "[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Object(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-3.5").unwrap(), Json::Float(-3.5));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c\n"}], "d": 1e3}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c\n"));
        assert_eq!(v.get("d").as_f64(), Some(1000.0));
    }

    #[test]
    fn roundtrips() {
        let text = r#"{"arr":[1,2.5,"x"],"nested":{"k":true,"n":null}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é \t café""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{e9} \t caf\u{e9}"));
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(*v.get("nope"), Json::Null);
    }
}
