//! Self-contained utility layer: JSON, RNG, CLI parsing, CRC32, property
//! testing, and a micro-benchmark timer. The offline crate registry lacks
//! serde / rand / clap / criterion / crc32fast, so these are first-class
//! modules with their own test suites instead of external dependencies.

pub mod cli;
pub mod crc32;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;
