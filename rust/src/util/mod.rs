//! Self-contained utility layer: JSON, RNG, CLI parsing, property testing,
//! and a micro-benchmark timer. The offline crate registry lacks serde /
//! rand / clap / criterion, so these are first-class modules with their own
//! test suites instead of external dependencies.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;
