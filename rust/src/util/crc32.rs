//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) — the same
//! checksum `crc32fast::hash` computes, implemented locally since the
//! offline registry has no crc32fast. Used by the GPack footer index.

/// Lookup table generated at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// CRC-32 of `data` (init 0xFFFFFFFF, final xor 0xFFFFFFFF).
pub fn hash(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = hash(b"hello world");
        let b = hash(b"hello worlc");
        assert_ne!(a, b);
    }
}
