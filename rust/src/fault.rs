//! Deterministic fault injection: the chaos half of the fault-tolerance
//! story.
//!
//! The paper's pre-training runs span Perlmutter, Aurora and Frontier,
//! where rank deaths, stragglers and corrupted files are routine — so the
//! recovery machinery (failure-aware [`Comm`](crate::comm::Comm)
//! collectives, [`Trainer::train_with_recovery`]
//! (crate::coordinator::trainer::Trainer::train_with_recovery), serve-worker
//! respawn) must be testable *deterministically*, not by waiting for real
//! hardware to die. A [`FaultPlan`] is a parsed schedule of injected
//! faults, threaded through `RunConfig.fault`, the `--faults` CLI flag, or
//! the `HYDRA_MTP_FAULTS` env var, and compiled to a no-op when empty
//! ([`FaultPlan::is_empty`] guards every hot-path query).
//!
//! ## Spec grammar
//!
//! Semicolon-separated entries, each `kind@key=value,key=value`:
//!
//! ```text
//! rank-panic@rank=1,epoch=2,step=0      thread panic before the step
//! stall@rank=0,epoch=1,step=3,ms=50     sleep injected before the step
//! nonfinite@epoch=1,batch=0[,rank=R]    loss overridden to NaN (rank 0 default)
//! corrupt-ckpt@epoch=2                  flip bytes in epoch_0002.ckpt after write
//! serve-panic@batch=0                   serve worker panics on batch attempt B
//! ```
//!
//! Trainer faults key on **(epoch, step-within-epoch)**, never a global
//! step counter — the coordinates stay well-defined across resume
//! boundaries. Every fault fires **at most once per plan instance**:
//! recovery shares one `Arc<FaultPlan>` across restart attempts, so an
//! injected rank kill cannot re-fire after the run resumes past it and
//! kill the job forever.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// One scheduled fault. Trainer faults carry (epoch, step) coordinates;
/// serving faults key on the worker-pool-wide batch attempt counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Rank `rank`'s training thread panics just before (epoch, step).
    RankPanic { rank: usize, epoch: usize, step: usize },
    /// Rank `rank` sleeps `ms` milliseconds before (epoch, step) — a
    /// straggler; with a short collective timeout it becomes a
    /// `CommError::Timeout` on its peers.
    CommStall { rank: usize, epoch: usize, step: usize, ms: u64 },
    /// Rank `rank`'s loss is overridden to NaN on batch `step` of `epoch`
    /// (exercises the skip-batch path).
    NonFiniteLoss { rank: usize, epoch: usize, step: usize },
    /// The checkpoint file written with `epochs_done == epoch` gets bytes
    /// flipped after the (atomic) write — exercises the CRC rescan.
    CorruptCheckpoint { epoch: usize },
    /// A serve worker panics while executing its `batch`-th batch attempt
    /// (pool-wide counter, starting at 0).
    ServePanic { batch: u64 },
}

/// A parsed, at-most-once-per-entry schedule of injected faults. Cheap to
/// query: every accessor early-outs on [`FaultPlan::is_empty`], so a run
/// with no faults configured pays one branch per step.
///
/// Not `Clone` (the fired flags are identity): share via `Arc`.
#[derive(Debug, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    fired: Vec<AtomicBool>,
    /// Serving batch-attempt counter (advanced by the worker pool).
    serve_attempts: AtomicU64,
}

impl FaultPlan {
    /// The empty plan: every query is a no-op.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse a fault spec (see the module docs for the grammar). An empty
    /// or whitespace-only spec yields the empty plan.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut faults = Vec::new();
        for entry in spec.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind, kvs) = match entry.split_once('@') {
                Some((k, rest)) => (k.trim(), parse_kvs(entry, rest)?),
                None => anyhow::bail!(
                    "fault entry '{entry}' missing '@' (expected kind@key=value,...)"
                ),
            };
            let get = |key: &str| -> anyhow::Result<u64> {
                kvs.iter()
                    .find(|(k, _)| k == key)
                    .map(|&(_, v)| v)
                    .ok_or_else(|| {
                        anyhow::anyhow!("fault entry '{entry}' missing '{key}='")
                    })
            };
            let opt = |key: &str| kvs.iter().find(|(k, _)| k == key).map(|&(_, v)| v);
            for (k, _) in &kvs {
                let known: &[&str] = match kind {
                    "rank-panic" => &["rank", "epoch", "step"],
                    "stall" => &["rank", "epoch", "step", "ms"],
                    "nonfinite" => &["rank", "epoch", "batch"],
                    "corrupt-ckpt" => &["epoch"],
                    "serve-panic" => &["batch"],
                    other => anyhow::bail!(
                        "unknown fault kind '{other}' in '{entry}' (expected \
                         rank-panic|stall|nonfinite|corrupt-ckpt|serve-panic)"
                    ),
                };
                anyhow::ensure!(
                    known.contains(&k.as_str()),
                    "fault entry '{entry}': unknown key '{k}' for kind '{kind}'"
                );
            }
            let fault = match kind {
                "rank-panic" => Fault::RankPanic {
                    rank: get("rank")? as usize,
                    epoch: get("epoch")? as usize,
                    step: get("step")? as usize,
                },
                "stall" => Fault::CommStall {
                    rank: get("rank")? as usize,
                    epoch: get("epoch")? as usize,
                    step: get("step")? as usize,
                    ms: get("ms")?,
                },
                "nonfinite" => Fault::NonFiniteLoss {
                    rank: opt("rank").unwrap_or(0) as usize,
                    epoch: get("epoch")? as usize,
                    step: get("batch")? as usize,
                },
                "corrupt-ckpt" => Fault::CorruptCheckpoint { epoch: get("epoch")? as usize },
                "serve-panic" => Fault::ServePanic { batch: get("batch")? },
                _ => unreachable!("kind validated above"),
            };
            faults.push(fault);
        }
        let fired = faults.iter().map(|_| AtomicBool::new(false)).collect();
        Ok(FaultPlan { faults, fired, serve_attempts: AtomicU64::new(0) })
    }

    /// Plan from the `HYDRA_MTP_FAULTS` env var (empty plan when unset or
    /// blank). The CI chaos job injects faults into CLI runs this way.
    pub fn from_env() -> anyhow::Result<FaultPlan> {
        match std::env::var("HYDRA_MTP_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec),
            _ => Ok(FaultPlan::none()),
        }
    }

    /// True when no faults are scheduled — the hot-path fast exit.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Scheduled entries (for logging/tests).
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Fire-once matcher: returns true for the first query matching
    /// `pred`, marking that entry consumed.
    fn take(&self, pred: impl Fn(&Fault) -> bool) -> Option<Fault> {
        for (i, f) in self.faults.iter().enumerate() {
            if pred(f)
                && self.fired[i]
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                return Some(*f);
            }
        }
        None
    }

    /// Should `rank` panic before (epoch, step)? Fires at most once.
    pub fn panic_at(&self, rank: usize, epoch: usize, step: usize) -> bool {
        if self.is_empty() {
            return false;
        }
        self.take(|f| {
            matches!(f, Fault::RankPanic { rank: r, epoch: e, step: s }
                if *r == rank && *e == epoch && *s == step)
        })
        .is_some()
    }

    /// Milliseconds `rank` should stall before (epoch, step), if any.
    pub fn stall_ms(&self, rank: usize, epoch: usize, step: usize) -> Option<u64> {
        if self.is_empty() {
            return None;
        }
        match self.take(|f| {
            matches!(f, Fault::CommStall { rank: r, epoch: e, step: s, .. }
                if *r == rank && *e == epoch && *s == step)
        }) {
            Some(Fault::CommStall { ms, .. }) => Some(ms),
            _ => None,
        }
    }

    /// Should `rank`'s loss on batch (epoch, step) be overridden to NaN?
    pub fn nonfinite_at(&self, rank: usize, epoch: usize, step: usize) -> bool {
        if self.is_empty() {
            return false;
        }
        self.take(|f| {
            matches!(f, Fault::NonFiniteLoss { rank: r, epoch: e, step: s }
                if *r == rank && *e == epoch && *s == step)
        })
        .is_some()
    }

    /// Should the checkpoint just written with `epochs_done == epoch` be
    /// corrupted?
    pub fn corrupt_after(&self, epoch: usize) -> bool {
        if self.is_empty() {
            return false;
        }
        self.take(|f| matches!(f, Fault::CorruptCheckpoint { epoch: e } if *e == epoch))
            .is_some()
    }

    /// Called by a serve worker per batch attempt: advances the pool-wide
    /// attempt counter and reports whether THIS attempt should panic.
    pub fn serve_panic_next(&self) -> bool {
        if self.is_empty() {
            return false;
        }
        let idx = self.serve_attempts.fetch_add(1, Ordering::AcqRel);
        self.take(|f| matches!(f, Fault::ServePanic { batch } if *batch == idx))
            .is_some()
    }
}

fn parse_kvs(entry: &str, rest: &str) -> anyhow::Result<Vec<(String, u64)>> {
    let mut out = Vec::new();
    for kv in rest.split(',') {
        let kv = kv.trim();
        if kv.is_empty() {
            continue;
        }
        let (k, v) = kv.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("fault entry '{entry}': '{kv}' is not key=value")
        })?;
        let v: u64 = v.trim().parse().map_err(|e| {
            anyhow::anyhow!("fault entry '{entry}': value of '{}' not a number: {e}", k.trim())
        })?;
        out.push((k.trim().to_string(), v));
    }
    anyhow::ensure!(!out.is_empty(), "fault entry '{entry}' has no key=value pairs");
    Ok(out)
}

/// Best-effort human-readable message from a caught panic payload. Shared
/// by the trainer's rank supervision and the serve workers' `catch_unwind`
/// recovery path.
pub fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Corrupt an on-disk checkpoint the way the CRC tests do: flip a byte in
/// the middle of the file (payload region, past the header), in place.
/// Used by the corrupt-ckpt fault and by tests building corrupt files.
pub fn corrupt_file(path: &Path) -> std::io::Result<()> {
    let mut bytes = std::fs::read(path)?;
    if bytes.is_empty() {
        return Ok(());
    }
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(path, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_noop() {
        let p = FaultPlan::parse("").unwrap();
        assert!(p.is_empty());
        assert!(!p.panic_at(0, 0, 0));
        assert!(p.stall_ms(0, 0, 0).is_none());
        assert!(!p.nonfinite_at(0, 0, 0));
        assert!(!p.corrupt_after(0));
        assert!(!p.serve_panic_next());
        assert!(FaultPlan::parse("  ; ;").unwrap().is_empty());
    }

    #[test]
    fn parses_every_kind() {
        let p = FaultPlan::parse(
            "rank-panic@rank=1,epoch=2,step=0; stall@rank=0,epoch=1,step=3,ms=50; \
             nonfinite@epoch=1,batch=4; corrupt-ckpt@epoch=2; serve-panic@batch=7",
        )
        .unwrap();
        assert_eq!(
            p.faults(),
            &[
                Fault::RankPanic { rank: 1, epoch: 2, step: 0 },
                Fault::CommStall { rank: 0, epoch: 1, step: 3, ms: 50 },
                Fault::NonFiniteLoss { rank: 0, epoch: 1, step: 4 },
                Fault::CorruptCheckpoint { epoch: 2 },
                Fault::ServePanic { batch: 7 },
            ]
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("rank-panic").is_err()); // no '@'
        assert!(FaultPlan::parse("explode@rank=0").is_err()); // unknown kind
        assert!(FaultPlan::parse("rank-panic@rank=0,epoch=1").is_err()); // missing step
        assert!(FaultPlan::parse("rank-panic@rank=x,epoch=1,step=0").is_err()); // NaN value
        assert!(FaultPlan::parse("corrupt-ckpt@epoch=1,rank=0").is_err()); // stray key
    }

    #[test]
    fn faults_fire_at_most_once() {
        let p = FaultPlan::parse("rank-panic@rank=1,epoch=2,step=0").unwrap();
        assert!(!p.panic_at(0, 2, 0), "wrong rank must not fire");
        assert!(!p.panic_at(1, 2, 1), "wrong step must not fire");
        assert!(p.panic_at(1, 2, 0), "exact match fires");
        assert!(!p.panic_at(1, 2, 0), "second query must NOT re-fire (recovery replay)");
    }

    #[test]
    fn stall_returns_duration_once() {
        let p = FaultPlan::parse("stall@rank=0,epoch=0,step=2,ms=25").unwrap();
        assert_eq!(p.stall_ms(0, 0, 2), Some(25));
        assert_eq!(p.stall_ms(0, 0, 2), None);
    }

    #[test]
    fn serve_panic_keys_on_attempt_counter() {
        let p = FaultPlan::parse("serve-panic@batch=1").unwrap();
        assert!(!p.serve_panic_next(), "attempt 0 passes");
        assert!(p.serve_panic_next(), "attempt 1 panics");
        assert!(!p.serve_panic_next(), "attempt 2 passes (fired once)");
    }

    #[test]
    fn corrupt_file_flips_a_payload_byte() {
        let path = std::env::temp_dir()
            .join(format!("hydra_mtp_fault_corrupt_{}.bin", std::process::id()));
        std::fs::write(&path, vec![0u8; 64]).unwrap();
        corrupt_file(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), 64, "corruption must not truncate");
        assert_eq!(bytes.iter().filter(|&&b| b != 0).count(), 1);
        std::fs::remove_file(&path).ok();
    }
}
