//! Graph-parallel EGNN: one (huge) structure's forward/backward domain-
//! decomposed across ranks, bit-identical to the same computation at any
//! other world size.
//!
//! The padded-batch engine ([`crate::model::egnn`]) holds every activation
//! of every structure in a batch at once — fine for molecules, impossible
//! for the bulk `supercell` / `amorphous_box` structures whose atom counts
//! exceed the whole batch budget. This module is the path for those: all
//! ranks step the SAME structure, each computing only the node work of its
//! owned atoms and the edge work of the edges it owns by destination (the
//! `O(atoms * hidden^2)` MLP cost), exchanging boundary hidden-state rows
//! before every EGNN block and reverse-exchanging boundary `d_x` gradient
//! rows once per block on the way back (see [`crate::comm::halo`]).
//!
//! **World-shape invariance.** The central guarantee — verified in
//! `rust/tests/integration_graph_parallel.rs` — is that losses, metrics and
//! every gradient element are *bit-identical* for worlds 1, 2, 4 and 8. It
//! is engineered, not observed:
//!
//! * all computation and every cross-rank sum is grouped by the fixed
//!   8-segment partition of [`crate::data::featurized::compute_segments`],
//!   never by rank: weight-gradient and loss contributions accumulate into
//!   per-segment f64 accumulators (rows in ascending global order within a
//!   segment), are combined through the slotted
//!   [`Comm::allreduce_sum_f64`] (one writer per slot), and every rank
//!   folds segments `0..8` in order. A world-sized fold would regroup the
//!   f64 additions and change bits;
//! * activations are exchanged at full f64 width, and the single-writer
//!   slot fold hands the owner's exact bits to every rank;
//! * row-level kernels ([`linear_into`] etc.) are row-independent, so
//!   computing a segment's rows as a compact matrix yields the same bits
//!   on whichever rank owns the segment.
//!
//! Consequently the `world = 1` run *is* the single-rank reference: it
//! walks the same segmented code path (its halo sets are empty, so the
//! exchanges are no-ops) and defines the bits every other world must
//! reproduce. Against the padded-batch engine the results agree only to
//! rounding (different summation grouping, f64 instead of f32 targets) —
//! pinned approximately in the tests below.
//!
//! **Checkpointing.** Only the per-layer *inputs* `h_in` (halo rows
//! included) are retained by the forward; each layer's internal
//! activations are recomputed segment-by-segment during the backward
//! sweep, the same recompute-from-block-boundary scheme as
//! [`crate::model::egnn::backward_checkpoint`]. Peak per-layer live memory
//! drops from nine `[E,H]`/`[N,H]` buffers to one `[N,H]` input per layer.
//!
//! **Precision.** This path always computes in f64 (the engine's oracle
//! precision), regardless of the session's [`Precision`] knob: halo
//! payloads are exchanged mid-computation, so any f32 round-trip would
//! break the N-rank == 1-rank guarantee. Both session precisions therefore
//! produce the same graph-parallel bits by construction.

use crate::comm::collectives::{Comm, CommError};
use crate::comm::halo::{HaloPlan, LOSS_SLOTS, SEGMENTS};
use crate::data::graph::Edge;
use crate::model::egnn::{BranchParams, EgnnDims, EncoderParams, LayerParams};
use crate::model::kernels::{
    colsum_into, dot, dsilu, grad_w_into, grad_x_into, linear_into, map_silu, mul_dsilu,
};
use crate::model::params::ParamSet;

// ---------------------------------------------------------------------------
// plan
// ---------------------------------------------------------------------------

/// One structure's graph-parallel work plan: the halo send/recv lists plus
/// per-segment node/edge work lists (all in ascending global order — the
/// accumulation order every world reproduces). Built once per structure
/// per world and reused across steps/epochs.
pub struct GpPlan {
    pub halo: HaloPlan,
    /// Atoms of each segment, ascending global atom index.
    seg_nodes: Vec<Vec<u32>>,
    /// Edges of each segment (keyed by `segment(dst)` — edge work follows
    /// the destination atom), ascending global edge index.
    seg_edges: Vec<Vec<u32>>,
    /// Position of each atom within its segment's `seg_nodes` list.
    node_slot: Vec<u32>,
}

impl GpPlan {
    pub fn build(segments: &[u8], edges: &[Edge], world: usize) -> GpPlan {
        let halo = HaloPlan::build(segments, edges, world);
        let mut seg_nodes: Vec<Vec<u32>> = vec![Vec::new(); SEGMENTS];
        for (a, &sg) in segments.iter().enumerate() {
            seg_nodes[sg as usize].push(a as u32);
        }
        let mut node_slot = vec![0u32; segments.len()];
        for sn in &seg_nodes {
            for (slot, &a) in sn.iter().enumerate() {
                node_slot[a as usize] = slot as u32;
            }
        }
        let mut seg_edges: Vec<Vec<u32>> = vec![Vec::new(); SEGMENTS];
        for (ei, ed) in edges.iter().enumerate() {
            seg_edges[segments[ed.dst as usize] as usize].push(ei as u32);
        }
        GpPlan { halo, seg_nodes, seg_edges, node_slot }
    }

    /// Segments rank `r` owns: `r*8/W..(r+1)*8/W`.
    pub fn owned_segments(&self, rank: usize) -> std::ops::Range<usize> {
        let w = self.halo.world();
        rank * SEGMENTS / w..(rank + 1) * SEGMENTS / w
    }

    /// Exact f64 elements one training step moves through `Comm`; see
    /// [`HaloPlan::predicted_step_elems`].
    pub fn predicted_step_elems(&self, hidden: usize, layers: usize, param_len: usize) -> u64 {
        self.halo.predicted_step_elems(hidden, layers, param_len)
    }
}

// ---------------------------------------------------------------------------
// gradient layout (the 8P segmented exchange)
// ---------------------------------------------------------------------------

/// Offsets of one flat-f64 gradient image of every parameter leaf, in the
/// fixed order `encoder.embed`, `encoder.layers.{li}.*`, `branch.*`. The
/// per-segment accumulator is 8 such images back to back; after the
/// exchange every rank folds the 8 segments per element.
pub struct GradLayout {
    embed: (usize, usize),
    /// Per layer: ew1, eb1, ew2, eb2, wg, bg, nw1, nb1, nw2, nb2.
    layers: Vec<[(usize, usize); 10]>,
    /// tw1, tb1, tw2, tb2, tw3, tb3, ew, eb, fw, fb.
    branch: [(usize, usize); 10],
    /// Total flat length P.
    pub len: usize,
}

impl GradLayout {
    pub fn new(dims: &EgnnDims) -> GradLayout {
        let (s, h, r, d, l) = (dims.s, dims.h, dims.r, dims.d, dims.l);
        let kx = 2 * h + r;
        let mut off = 0usize;
        let mut span = |len: usize| {
            let o = (off, len);
            off += len;
            o
        };
        let embed = span(s * h);
        let layers = (0..l)
            .map(|_| {
                [
                    span(kx * h), // ew1
                    span(h),      // eb1
                    span(h * h),  // ew2
                    span(h),      // eb2
                    span(h),      // wg
                    span(1),      // bg
                    span(2 * h * h), // nw1
                    span(h),      // nb1
                    span(h * h),  // nw2
                    span(h),      // nb2
                ]
            })
            .collect();
        let branch = [
            span(h * d), // tw1
            span(d),     // tb1
            span(d * d), // tw2
            span(d),     // tb2
            span(d * d), // tw3
            span(d),     // tb3
            span(d),     // ew
            span(1),     // eb
            span(d),     // fw
            span(1),     // fb
        ];
        GradLayout { embed, layers, branch, len: off }
    }

    /// Downcast the folded flat gradient image into the named f32 leaves of
    /// `grads` (the exact `ParamSet` structure the optimizer and the DDP
    /// collectives consume).
    pub fn write_into(&self, flat: &[f64], grads: &mut ParamSet) -> anyhow::Result<()> {
        debug_assert_eq!(flat.len(), self.len);
        let mut write = |name: &str, (off, len): (usize, usize)| -> anyhow::Result<()> {
            let t = grads
                .get_mut(name)
                .ok_or_else(|| anyhow::anyhow!("gradient for unknown leaf '{name}'"))?;
            let dst = t.as_f32_mut();
            anyhow::ensure!(
                dst.len() == len,
                "gradient leaf '{name}': {len} values, expected {}",
                dst.len()
            );
            for (o, &v) in dst.iter_mut().zip(&flat[off..off + len]) {
                *o = v as f32;
            }
            Ok(())
        };
        write("encoder.embed", self.embed)?;
        const LAYER_PARTS: [&str; 10] = [
            "edge.w1", "edge.b1", "edge.w2", "edge.b2", "edge.wg", "edge.bg", "node.w1",
            "node.b1", "node.w2", "node.b2",
        ];
        for (li, spans) in self.layers.iter().enumerate() {
            for (part, &sp) in LAYER_PARTS.iter().zip(spans.iter()) {
                write(&format!("encoder.layers.{li}.{part}"), sp)?;
            }
        }
        const BRANCH_PARTS: [&str; 10] = [
            "branch.trunk.w1",
            "branch.trunk.b1",
            "branch.trunk.w2",
            "branch.trunk.b2",
            "branch.trunk.w3",
            "branch.trunk.b3",
            "branch.energy.w",
            "branch.energy.b",
            "branch.force.w",
            "branch.force.b",
        ];
        for (part, &sp) in BRANCH_PARTS.iter().zip(self.branch.iter()) {
            write(part, sp)?;
        }
        Ok(())
    }
}

/// Mutable per-segment view into the `8 x P` accumulator.
#[inline]
fn seg(acc: &mut [f64], p_len: usize, s: usize, (off, len): (usize, usize)) -> &mut [f64] {
    &mut acc[s * p_len + off..s * p_len + off + len]
}

// ---------------------------------------------------------------------------
// input + outputs
// ---------------------------------------------------------------------------

/// One structure's graph-parallel training example (borrowed from the
/// [`crate::data::featurized::FeaturizedStore`] caches). Targets stay f64
/// end to end — no padded-batch f32 round trip.
pub struct GpStructure<'a> {
    pub species: &'a [u8],
    pub edges: &'a [Edge],
    /// Labeled energy per atom.
    pub y_energy_per_atom: f64,
    /// Labeled forces `[N][3]`.
    pub y_forces: &'a [[f64; 3]],
}

/// Scalar outputs of one graph-parallel step, identical on every rank.
#[derive(Debug, Clone, Copy)]
pub struct GpOut {
    pub loss: f64,
    pub mae_e: f64,
    pub mae_f: f64,
}

// ---------------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------------

/// Immutable per-step context shared by the forward and backward sweeps.
struct Ctx<'a> {
    h: usize,
    r: usize,
    kx: usize,
    st: &'a GpStructure<'a>,
    plan: &'a GpPlan,
    rbf: Vec<f64>,
    inv_deg: Vec<f64>,
}

/// One segment's recomputable layer activations (compact rows in the
/// segment's ascending node/edge order).
struct SegFwd {
    x: Vec<f64>,    // [ec, 2H+R] edge-MLP input
    ae1: Vec<f64>,  // [ec, H]
    u: Vec<f64>,    // [ec, H]
    ae2: Vec<f64>,  // [ec, H]
    m: Vec<f64>,    // [ec, H]
    gate: Vec<f64>, // [ec]
    nin: Vec<f64>,  // [nc, 2H]
    an1: Vec<f64>,  // [nc, H]
    s1: Vec<f64>,   // [nc, H]
    upd: Vec<f64>,  // [nc, H]
}

/// Recompute one segment's slice of one EGNN block from the layer input
/// `h_in` (halo rows valid). Pure f64; identical bits on every world.
fn layer_seg_forward(cx: &Ctx, lp: &LayerParams, h_in: &[f64], s: usize) -> SegFwd {
    let (h, r, kx) = (cx.h, cx.r, cx.kx);
    let edges_s = &cx.plan.seg_edges[s];
    let nodes_s = &cx.plan.seg_nodes[s];
    let (ec, nc) = (edges_s.len(), nodes_s.len());

    let mut x = vec![0.0; ec * kx];
    for (row, &ei) in edges_s.iter().enumerate() {
        let ed = &cx.st.edges[ei as usize];
        let (si, di) = (ed.src as usize, ed.dst as usize);
        let rw = &mut x[row * kx..(row + 1) * kx];
        rw[..h].copy_from_slice(&h_in[si * h..(si + 1) * h]);
        rw[h..2 * h].copy_from_slice(&h_in[di * h..(di + 1) * h]);
        rw[2 * h..].copy_from_slice(&cx.rbf[ei as usize * r..(ei as usize + 1) * r]);
    }
    let mut ae1 = vec![0.0; ec * h];
    linear_into(&x, &lp.ew1, &lp.eb1, &mut ae1, ec, kx, h);
    let u = map_silu(&ae1);
    let mut ae2 = vec![0.0; ec * h];
    linear_into(&u, &lp.ew2, &lp.eb2, &mut ae2, ec, h, h);
    let m = map_silu(&ae2);
    let mut gate = vec![0.0; ec];
    for row in 0..ec {
        gate[row] = (dot(&m[row * h..(row + 1) * h], &lp.wg) + lp.bg).tanh();
    }

    // Scatter-sum of messages per destination atom, in ascending global
    // edge order (each atom's per-contribution addition order matches the
    // engine's full serial loop restricted to that atom).
    let mut hagg = vec![0.0; nc * h];
    for (row, &ei) in edges_s.iter().enumerate() {
        let di = cx.st.edges[ei as usize].dst as usize;
        let slot = cx.plan.node_slot[di] as usize;
        for j in 0..h {
            hagg[slot * h + j] += m[row * h + j];
        }
    }

    let mut nin = vec![0.0; nc * 2 * h];
    for (slot, &a) in nodes_s.iter().enumerate() {
        let a = a as usize;
        nin[slot * 2 * h..slot * 2 * h + h].copy_from_slice(&h_in[a * h..(a + 1) * h]);
        let id = cx.inv_deg[a];
        for j in 0..h {
            nin[slot * 2 * h + h + j] = hagg[slot * h + j] * id;
        }
    }
    let mut an1 = vec![0.0; nc * h];
    linear_into(&nin, &lp.nw1, &lp.nb1, &mut an1, nc, 2 * h, h);
    let s1 = map_silu(&an1);
    let mut upd = vec![0.0; nc * h];
    linear_into(&s1, &lp.nw2, &lp.nb2, &mut upd, nc, h, h);
    SegFwd { x, ae1, u, ae2, m, gate, nin, an1, s1, upd }
}

/// Forward state retained for the backward sweep. Only the per-layer
/// inputs are kept (the checkpointing scheme); everything else is either
/// owned-rows-only or scalar.
struct GpForward {
    /// Layer inputs `[L][N,H]`, halo rows valid (exchanged in forward).
    saved_h: Vec<Vec<f64>>,
    /// Final hidden state `[N,H]`, owned rows valid.
    h: Vec<f64>,
    /// Equivariant channel `[N,3]`, owned rows valid.
    v: Vec<f64>,
    // Branch intermediates, owned rows valid (not checkpointed — one set,
    // like the engine).
    at1: Vec<f64>,
    z1: Vec<f64>,
    at2: Vec<f64>,
    z2: Vec<f64>,
    at3: Vec<f64>,
    z3: Vec<f64>,
    fr: Vec<f64>,
    forces: Vec<f64>,
    /// Energy-prediction residual (global, identical on every rank).
    de: f64,
    out: GpOut,
}

/// Shared forward: encoder with per-block halo exchange, branch over owned
/// atoms, segment-folded loss. Every rank returns identical scalars.
fn forward(
    cx: &Ctx,
    enc: &EncoderParams,
    br: &BranchParams,
    dims: &EgnnDims,
    comm: &Comm,
) -> Result<GpForward, CommError> {
    let st = cx.st;
    let plan = cx.plan;
    let n = st.species.len();
    let (h, d) = (cx.h, dims.d);
    let rank = comm.rank_in_group;
    let segs = plan.owned_segments(rank);

    // h0 = embed[species] for owned atoms (node masks are all 1 here —
    // there is no padding on this path).
    let mut hbuf = vec![0.0; n * h];
    for s in segs.clone() {
        for &a in &plan.seg_nodes[s] {
            let a = a as usize;
            let sp = (st.species[a] as usize).min(dims.s - 1);
            hbuf[a * h..(a + 1) * h].copy_from_slice(&enc.embed[sp * h..(sp + 1) * h]);
        }
    }
    let mut v = vec![0.0; n * 3];

    let mut saved_h = Vec::with_capacity(dims.l);
    for lp in &enc.layers {
        // Boundary hidden rows before EVERY block (the layer-0 exchange
        // delivers the owner's embedding rows).
        plan.halo.exchange_node_rows(comm, &mut hbuf, h)?;
        let h_in = hbuf.clone();
        for s in segs.clone() {
            let sf = layer_seg_forward(cx, lp, &h_in, s);
            // Equivariant update (forward only; `v` never crosses ranks —
            // it is written and read strictly per owned destination atom).
            for (row, &ei) in plan.seg_edges[s].iter().enumerate() {
                let ed = &st.edges[ei as usize];
                let di = ed.dst as usize;
                let sc = sf.gate[row] * cx.inv_deg[di];
                for k in 0..3 {
                    v[di * 3 + k] += ed.rel_hat[k] as f64 * sc;
                }
            }
            // Residual node update; reads go through the saved `h_in`
            // clone, so overwriting `hbuf` rows segment-by-segment is safe.
            for (slot, &a) in plan.seg_nodes[s].iter().enumerate() {
                let a = a as usize;
                for j in 0..h {
                    hbuf[a * h + j] = h_in[a * h + j] + sf.upd[slot * h + j];
                }
            }
        }
        saved_h.push(h_in);
    }

    // Branch over owned atoms, segment by segment (compact rows scattered
    // back to global-node-indexed buffers for the backward pass).
    let mut at1 = vec![0.0; n * d];
    let mut z1 = vec![0.0; n * d];
    let mut at2 = vec![0.0; n * d];
    let mut z2 = vec![0.0; n * d];
    let mut at3 = vec![0.0; n * d];
    let mut z3 = vec![0.0; n * d];
    let mut er = vec![0.0; n];
    let mut fr = vec![0.0; n];
    let mut forces = vec![0.0; n * 3];
    for s in segs.clone() {
        let nodes_s = &plan.seg_nodes[s];
        let nc = nodes_s.len();
        let mut xh = vec![0.0; nc * h];
        for (slot, &a) in nodes_s.iter().enumerate() {
            let a = a as usize;
            xh[slot * h..(slot + 1) * h].copy_from_slice(&hbuf[a * h..(a + 1) * h]);
        }
        let mut at1c = vec![0.0; nc * d];
        linear_into(&xh, &br.tw1, &br.tb1, &mut at1c, nc, h, d);
        let z1c = map_silu(&at1c);
        let mut at2c = vec![0.0; nc * d];
        linear_into(&z1c, &br.tw2, &br.tb2, &mut at2c, nc, d, d);
        let z2c = map_silu(&at2c);
        let mut at3c = vec![0.0; nc * d];
        linear_into(&z2c, &br.tw3, &br.tb3, &mut at3c, nc, d, d);
        let z3c = map_silu(&at3c);
        for (slot, &a) in nodes_s.iter().enumerate() {
            let a = a as usize;
            let zrow = &z3c[slot * d..(slot + 1) * d];
            er[a] = dot(zrow, &br.ew) + br.eb;
            fr[a] = dot(zrow, &br.fw) + br.fb;
            for k in 0..3 {
                forces[a * 3 + k] = fr[a] * v[a * 3 + k];
            }
            at1[a * d..(a + 1) * d].copy_from_slice(&at1c[slot * d..(slot + 1) * d]);
            z1[a * d..(a + 1) * d].copy_from_slice(&z1c[slot * d..(slot + 1) * d]);
            at2[a * d..(a + 1) * d].copy_from_slice(&at2c[slot * d..(slot + 1) * d]);
            z2[a * d..(a + 1) * d].copy_from_slice(&z2c[slot * d..(slot + 1) * d]);
            at3[a * d..(a + 1) * d].copy_from_slice(&at3c[slot * d..(slot + 1) * d]);
            z3[a * d..(a + 1) * d].copy_from_slice(zrow);
        }
    }

    // Loss: per-segment partial sums -> one 24-slot exchange -> every rank
    // folds segments 0..8 in order. The fold grouping is the segment
    // partition, never the world shape.
    let mut buf = [0.0f64; LOSS_SLOTS];
    for s in segs.clone() {
        let nodes_s = &plan.seg_nodes[s];
        let (mut ep, mut sfp, mut afp) = (0.0, 0.0, 0.0);
        for &a in nodes_s {
            ep += er[a as usize];
        }
        for &a in nodes_s {
            let a = a as usize;
            for k in 0..3 {
                let df = forces[a * 3 + k] - st.y_forces[a][k];
                sfp += df * df;
                afp += df.abs();
            }
        }
        buf[s] = ep;
        buf[SEGMENTS + s] = sfp;
        buf[2 * SEGMENTS + s] = afp;
    }
    comm.allreduce_sum_f64(&mut buf)?;
    let (mut e_sum, mut sf_sum, mut af_sum) = (0.0, 0.0, 0.0);
    for s in 0..SEGMENTS {
        e_sum += buf[s];
    }
    for s in 0..SEGMENTS {
        sf_sum += buf[SEGMENTS + s];
    }
    for s in 0..SEGMENTS {
        af_sum += buf[2 * SEGMENTS + s];
    }
    let n_f = n as f64;
    let e_pa = e_sum * (1.0 / n_f);
    let de = e_pa - st.y_energy_per_atom;
    let mse_e = de * de; // one graph
    let mse_f = sf_sum / (3.0 * n_f);
    let out = GpOut {
        loss: dims.w_energy * mse_e + dims.w_force * mse_f,
        mae_e: de.abs(),
        mae_f: af_sum / (3.0 * n_f),
    };
    Ok(GpForward { saved_h, h: hbuf, v, at1, z1, at2, z2, at3, z3, fr, forces, de, out })
}

/// Build the shared per-step context (RBF + degree normalization are pure
/// functions of the structure, computed identically on every rank).
fn build_ctx<'a>(dims: &EgnnDims, st: &'a GpStructure<'a>, plan: &'a GpPlan) -> Ctx<'a> {
    let (h, r) = (dims.h, dims.r);
    let e = st.edges.len();
    let n = st.species.len();
    let mut rbf = vec![0.0; e * r];
    let gamma = (r as f64 / dims.cutoff).powi(2);
    for (ei, ed) in st.edges.iter().enumerate() {
        let dist = ed.dist as f64;
        let env =
            0.5 * ((std::f64::consts::PI * (dist / dims.cutoff).clamp(0.0, 1.0)).cos() + 1.0);
        for ri in 0..r {
            let c = if r > 1 { dims.cutoff * ri as f64 / (r - 1) as f64 } else { 0.0 };
            let dd = dist - c;
            rbf[ei * r + ri] = (-gamma * dd * dd).exp() * env;
        }
    }
    let mut deg = vec![0.0f64; n];
    for ed in st.edges {
        deg[ed.dst as usize] += 1.0;
    }
    let inv_deg: Vec<f64> = deg.iter().map(|&x| 1.0 / (1.0 + x)).collect();
    Ctx { h, r, kx: 2 * h + r, st, plan, rbf, inv_deg }
}

/// Evaluation-only graph-parallel pass: forward + the loss exchange.
pub fn eval_step(
    dims: &EgnnDims,
    enc: &EncoderParams,
    br: &BranchParams,
    st: &GpStructure,
    plan: &GpPlan,
    comm: &Comm,
) -> Result<GpOut, CommError> {
    let cx = build_ctx(dims, st, plan);
    Ok(forward(&cx, enc, br, dims, comm)?.out)
}

// ---------------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------------

/// One graph-parallel training step: forward (with per-block halo
/// exchange), segment-folded loss, checkpointed backward (recompute per
/// segment, reverse `d_x` halo per block), and the `8 x P` segmented
/// gradient fold. Returns the step scalars plus the flat f64 gradient
/// image (layout per [`GradLayout`]) — both bit-identical on every rank of
/// every world.
pub fn train_step(
    dims: &EgnnDims,
    enc: &EncoderParams,
    br: &BranchParams,
    st: &GpStructure,
    plan: &GpPlan,
    layout: &GradLayout,
    comm: &Comm,
) -> Result<(GpOut, Vec<f64>), CommError> {
    let cx = build_ctx(dims, st, plan);
    let fwd = forward(&cx, enc, br, dims, comm)?;
    let n = st.species.len();
    let (h, d, kx) = (cx.h, dims.d, cx.kx);
    let rank = comm.rank_in_group;
    let segs = plan.owned_segments(rank);
    let p_len = layout.len;
    let mut acc = vec![0.0f64; SEGMENTS * p_len];

    // Loss seeds. d_e_pa is global (one graph, graph mask 1); force seeds
    // are per owned atom.
    let n_f = n as f64;
    let d_e_pa = dims.w_energy * 2.0 * fwd.de;
    let denom_f = 3.0 * n_f;
    let inv_atoms = 1.0 / n_f;

    // --- branch backward (per owned segment) ---
    let [tw1s, tb1s, tw2s, tb2s, tw3s, tb3s, ews, ebs, fws, fbs] = layout.branch;
    let mut d_h = vec![0.0; n * h];
    let mut d_v = vec![0.0; n * 3];
    for s in segs.clone() {
        let nodes_s = &plan.seg_nodes[s];
        let nc = nodes_s.len();
        let mut d_z3 = vec![0.0; nc * d];
        for (slot, &a) in nodes_s.iter().enumerate() {
            let a = a as usize;
            let d_er = d_e_pa * inv_atoms;
            let mut d_fr = 0.0;
            for k in 0..3 {
                let df = fwd.forces[a * 3 + k] - st.y_forces[a][k];
                let d_f = dims.w_force * 2.0 * df / denom_f;
                d_fr += d_f * fwd.v[a * 3 + k];
                d_v[a * 3 + k] = d_f * fwd.fr[a];
            }
            seg(&mut acc, p_len, s, ebs)[0] += d_er;
            seg(&mut acc, p_len, s, fbs)[0] += d_fr;
            if d_er == 0.0 && d_fr == 0.0 {
                continue;
            }
            let zrow = &fwd.z3[a * d..(a + 1) * d];
            {
                let ew_acc = seg(&mut acc, p_len, s, ews);
                for j in 0..d {
                    ew_acc[j] += zrow[j] * d_er;
                }
            }
            {
                let fw_acc = seg(&mut acc, p_len, s, fws);
                for j in 0..d {
                    fw_acc[j] += zrow[j] * d_fr;
                }
            }
            let drow = &mut d_z3[slot * d..(slot + 1) * d];
            for j in 0..d {
                drow[j] = d_er * br.ew[j] + d_fr * br.fw[j];
            }
        }
        // Gather the compact trunk activations of this segment.
        let gather = |src: &[f64], width: usize| -> Vec<f64> {
            let mut out = vec![0.0; nc * width];
            for (slot, &a) in nodes_s.iter().enumerate() {
                let a = a as usize;
                out[slot * width..(slot + 1) * width]
                    .copy_from_slice(&src[a * width..(a + 1) * width]);
            }
            out
        };
        let at3c = gather(&fwd.at3, d);
        let z2c = gather(&fwd.z2, d);
        let at2c = gather(&fwd.at2, d);
        let z1c = gather(&fwd.z1, d);
        let at1c = gather(&fwd.at1, d);
        let xhc = gather(&fwd.h, h);

        let d_at3 = mul_dsilu(&d_z3, &at3c);
        grad_w_into(&z2c, &d_at3, seg(&mut acc, p_len, s, tw3s), nc, d, d);
        colsum_into(&d_at3, seg(&mut acc, p_len, s, tb3s), nc, d);
        let mut d_z2 = vec![0.0; nc * d];
        grad_x_into(&d_at3, &br.tw3, &mut d_z2, nc, d, d);
        let d_at2 = mul_dsilu(&d_z2, &at2c);
        grad_w_into(&z1c, &d_at2, seg(&mut acc, p_len, s, tw2s), nc, d, d);
        colsum_into(&d_at2, seg(&mut acc, p_len, s, tb2s), nc, d);
        let mut d_z1 = vec![0.0; nc * d];
        grad_x_into(&d_at2, &br.tw2, &mut d_z1, nc, d, d);
        let d_at1 = mul_dsilu(&d_z1, &at1c);
        grad_w_into(&xhc, &d_at1, seg(&mut acc, p_len, s, tw1s), nc, h, d);
        colsum_into(&d_at1, seg(&mut acc, p_len, s, tb1s), nc, d);
        let mut d_hc = vec![0.0; nc * h];
        grad_x_into(&d_at1, &br.tw1, &mut d_hc, nc, h, d);
        for (slot, &a) in nodes_s.iter().enumerate() {
            let a = a as usize;
            d_h[a * h..(a + 1) * h].copy_from_slice(&d_hc[slot * h..(slot + 1) * h]);
        }
    }

    // --- encoder backward: reverse layer sweep with per-segment recompute
    // (checkpointing) and one reverse d_x halo per block ---
    for li in (0..dims.l).rev() {
        let lp = &enc.layers[li];
        let [ew1s, eb1s, ew2s, eb2s, wgs, bgs, nw1s, nb1s, nw2s, nb2s] = layout.layers[li];
        let h_in = &fwd.saved_h[li];
        let mut d_h_in = vec![0.0; n * h];
        let mut d_x = vec![0.0; st.edges.len() * kx];
        for s in segs.clone() {
            let sf = layer_seg_forward(&cx, lp, h_in, s);
            let nodes_s = &plan.seg_nodes[s];
            let edges_s = &plan.seg_edges[s];
            let (nc, ec) = (nodes_s.len(), edges_s.len());

            // Node update backward: h_out = h_in + upd (masks all 1).
            let mut d_pre = vec![0.0; nc * h];
            for (slot, &a) in nodes_s.iter().enumerate() {
                let a = a as usize;
                d_pre[slot * h..(slot + 1) * h].copy_from_slice(&d_h[a * h..(a + 1) * h]);
                d_h_in[a * h..(a + 1) * h].copy_from_slice(&d_h[a * h..(a + 1) * h]);
            }
            grad_w_into(&sf.s1, &d_pre, seg(&mut acc, p_len, s, nw2s), nc, h, h);
            colsum_into(&d_pre, seg(&mut acc, p_len, s, nb2s), nc, h);
            let mut d_s1 = vec![0.0; nc * h];
            grad_x_into(&d_pre, &lp.nw2, &mut d_s1, nc, h, h);
            let d_an1 = mul_dsilu(&d_s1, &sf.an1);
            grad_w_into(&sf.nin, &d_an1, seg(&mut acc, p_len, s, nw1s), nc, 2 * h, h);
            colsum_into(&d_an1, seg(&mut acc, p_len, s, nb1s), nc, h);
            let mut d_nin = vec![0.0; nc * 2 * h];
            grad_x_into(&d_an1, &lp.nw1, &mut d_nin, nc, 2 * h, h);
            let mut d_hagg = vec![0.0; nc * h];
            for (slot, &a) in nodes_s.iter().enumerate() {
                let a = a as usize;
                let id = cx.inv_deg[a];
                for j in 0..h {
                    d_h_in[a * h + j] += d_nin[slot * 2 * h + j];
                    d_hagg[slot * h + j] = d_nin[slot * 2 * h + h + j] * id;
                }
            }

            // Edge backward: message + gate paths (edge masks all 1).
            let mut d_m = vec![0.0; ec * h];
            let mut d_ag = vec![0.0; ec];
            for (row, &ei) in edges_s.iter().enumerate() {
                let ed = &st.edges[ei as usize];
                let di = ed.dst as usize;
                let slot = plan.node_slot[di] as usize;
                for j in 0..h {
                    d_m[row * h + j] = d_hagg[slot * h + j];
                }
                let sc = cx.inv_deg[di];
                let mut dg = 0.0;
                for k in 0..3 {
                    dg += d_v[di * 3 + k] * ed.rel_hat[k] as f64;
                }
                let t = sf.gate[row];
                d_ag[row] = dg * sc * (1.0 - t * t);
            }
            for row in 0..ec {
                let da = d_ag[row];
                seg(&mut acc, p_len, s, bgs)[0] += da;
                if da == 0.0 {
                    continue;
                }
                let mrow = &sf.m[row * h..(row + 1) * h];
                let wg_acc = seg(&mut acc, p_len, s, wgs);
                for j in 0..h {
                    wg_acc[j] += mrow[j] * da;
                }
                let drow = &mut d_m[row * h..(row + 1) * h];
                for j in 0..h {
                    drow[j] += da * lp.wg[j];
                }
            }
            let mut d_ae2 = vec![0.0; ec * h];
            for i in 0..ec * h {
                d_ae2[i] = d_m[i] * dsilu(sf.ae2[i]);
            }
            grad_w_into(&sf.u, &d_ae2, seg(&mut acc, p_len, s, ew2s), ec, h, h);
            colsum_into(&d_ae2, seg(&mut acc, p_len, s, eb2s), ec, h);
            let mut d_u = vec![0.0; ec * h];
            grad_x_into(&d_ae2, &lp.ew2, &mut d_u, ec, h, h);
            let d_ae1 = mul_dsilu(&d_u, &sf.ae1);
            grad_w_into(&sf.x, &d_ae1, seg(&mut acc, p_len, s, ew1s), ec, kx, h);
            colsum_into(&d_ae1, seg(&mut acc, p_len, s, eb1s), ec, h);
            let mut d_xc = vec![0.0; ec * kx];
            grad_x_into(&d_ae1, &lp.ew1, &mut d_xc, ec, kx, h);
            for (row, &ei) in edges_s.iter().enumerate() {
                d_x[ei as usize * kx..(ei as usize + 1) * kx]
                    .copy_from_slice(&d_xc[row * kx..(row + 1) * kx]);
            }
        }

        // Reverse halo: boundary edges' src-part gradient rows travel from
        // owner(dst) (who computed them) to everyone.
        plan.halo.exchange_edge_rows(comm, &mut d_x, kx, h)?;

        // Fold edge contributions into owned atoms in GLOBAL edge order —
        // the engine's exact per-atom addition sequence.
        for (ei, ed) in st.edges.iter().enumerate() {
            let (si, di) = (ed.src as usize, ed.dst as usize);
            if plan.halo.owner(si) == rank {
                for j in 0..h {
                    d_h_in[si * h + j] += d_x[ei * kx + j];
                }
            }
            if plan.halo.owner(di) == rank {
                for j in 0..h {
                    d_h_in[di * h + j] += d_x[ei * kx + h + j];
                }
            }
        }
        d_h = d_h_in;
    }

    // Embedding gradient (per owned segment).
    for s in segs.clone() {
        for &a in &plan.seg_nodes[s] {
            let a = a as usize;
            let sp = (st.species[a] as usize).min(dims.s - 1);
            let emb_acc = seg(&mut acc, p_len, s, layout.embed);
            for j in 0..h {
                emb_acc[sp * h + j] += d_h[a * h + j];
            }
        }
    }

    // The 8 x P segmented gradient fold: owners deposit their segments'
    // images (the rest stay 0.0), one exchange, then every rank folds
    // segments 0..8 per element — the world-invariant grouping.
    comm.allreduce_sum_f64(&mut acc)?;
    let mut flat = vec![0.0f64; p_len];
    for s in 0..SEGMENTS {
        let base = s * p_len;
        for (i, f) in flat.iter_mut().enumerate() {
            *f += acc[base + i];
        }
    }
    Ok((fwd.out, flat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collectives::run_group;
    use crate::data::batch::BatchPool;
    use crate::data::generators::inorganic::build_crystal;
    use crate::data::graph::radius_graph_positions;
    use crate::model::kernels::Precision;
    use crate::runtime::backend::Backend;
    use crate::runtime::manifest::{Manifest, ManifestConfig};
    use crate::util::rng::Rng;

    fn test_structure(natoms: usize) -> (Vec<u8>, Vec<[f64; 3]>, Vec<[f64; 3]>, f64) {
        let mut rng = Rng::new(42);
        let (species, positions) = build_crystal(&mut rng, &[12, 8, 11, 17], natoms);
        let (energy, forces) =
            crate::data::potential::energy_and_forces(&species, &positions);
        (species, positions, forces, energy / natoms as f64)
    }

    fn manifest() -> Manifest {
        Manifest::synthesize(ManifestConfig::default_native())
    }

    #[test]
    fn grad_layout_covers_every_parameter() {
        let m = manifest();
        let dims = EgnnDims::from_config(&m.config);
        let layout = GradLayout::new(&dims);
        let params = ParamSet::init(&m.params, 7);
        assert_eq!(layout.len, params.total_params());
        let mut grads = ParamSet::zeros_like(&m.params);
        let flat: Vec<f64> = (0..layout.len).map(|i| i as f64).collect();
        layout.write_into(&flat, &mut grads).unwrap();
        // Spot-check: the embed leaf holds the first S*H values.
        let emb = grads.get("encoder.embed").unwrap().as_f32();
        assert_eq!(emb[0], 0.0);
        assert_eq!(emb[1], 1.0);
        assert_eq!(grads.get("branch.force.b").unwrap().as_f32()[0], (layout.len - 1) as f32);
    }

    #[test]
    fn world_one_tracks_the_padded_engine() {
        // Same structure through the graph-parallel path (world 1) and the
        // padded-batch engine: losses agree to rounding (the paths group
        // f64 sums differently and the engine's targets round through f32).
        let m = manifest();
        let dims = EgnnDims::from_config(&m.config);
        let params = ParamSet::init(&m.params, 3);
        let (species, positions, forces, y_epa) = test_structure(30);
        let edges = radius_graph_positions(&positions, m.config.cutoff);
        let segments = crate::data::featurized::compute_segments(&positions, m.config.cutoff);

        let plan = GpPlan::build(&segments, &edges, 1);
        let layout = GradLayout::new(&dims);
        let st = GpStructure {
            species: &species,
            edges: &edges,
            y_energy_per_atom: y_epa,
            y_forces: &forces,
        };
        let enc = EncoderParams::from_set(&dims, &params).unwrap();
        let br = BranchParams::from_set(&dims, &params).unwrap();
        let comms = crate::comm::Comm::group(1);
        let (out, flat) =
            train_step(&dims, &enc, &br, &st, &plan, &layout, &comms[0]).unwrap();

        let mut pool = BatchPool::new();
        let mut batch = pool.acquire(m.config.batch_dims());
        batch
            .push_raw(&species, &forces, y_epa, &edges)
            .expect("structure fits the default batch dims");
        let backend = crate::runtime::native::NativeBackend::new(Precision::F64);
        let step = backend.train_step(&m, &params, &batch).unwrap();

        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-12);
        assert!(rel(out.loss, step.loss) < 1e-4, "loss {} vs {}", out.loss, step.loss);
        assert!(rel(out.mae_f, step.mae_f) < 1e-4, "mae_f {} vs {}", out.mae_f, step.mae_f);
        // Gradients agree loosely too (same math, different fold grouping
        // and target precision).
        let g_engine: f64 =
            step.grads.get("branch.energy.b").unwrap().as_f32()[0] as f64;
        let mut grads = ParamSet::zeros_like(&m.params);
        layout.write_into(&flat, &mut grads).unwrap();
        let g_gp: f64 = grads.get("branch.energy.b").unwrap().as_f32()[0] as f64;
        assert!(rel(g_gp, g_engine) < 1e-3, "d eb {g_gp} vs {g_engine}");
    }

    #[test]
    fn train_step_is_bit_identical_across_worlds() {
        let m = manifest();
        let dims = EgnnDims::from_config(&m.config);
        let params = ParamSet::init(&m.params, 11);
        let (species, positions, forces, y_epa) = test_structure(24);
        let edges = radius_graph_positions(&positions, m.config.cutoff);
        let segments = crate::data::featurized::compute_segments(&positions, m.config.cutoff);
        let layout = GradLayout::new(&dims);
        let enc = EncoderParams::from_set(&dims, &params).unwrap();
        let br = BranchParams::from_set(&dims, &params).unwrap();

        let mut reference: Option<(u64, Vec<u64>)> = None;
        for world in [1usize, 2, 4] {
            let plan = GpPlan::build(&segments, &edges, world);
            let st = GpStructure {
                species: &species,
                edges: &edges,
                y_energy_per_atom: y_epa,
                y_forces: &forces,
            };
            let results = run_group(world, |c| {
                train_step(&dims, &enc, &br, &st, &plan, &layout, &c).unwrap()
            });
            for r in results {
                let (out, flat) = r.unwrap();
                let bits: Vec<u64> = flat.iter().map(|x| x.to_bits()).collect();
                match &reference {
                    None => reference = Some((out.loss.to_bits(), bits)),
                    Some((lref, gref)) => {
                        assert_eq!(out.loss.to_bits(), *lref, "world {world} loss bits");
                        assert_eq!(&bits, gref, "world {world} gradient bits");
                    }
                }
            }
        }
    }
}
