//! AdamW optimizer (paper Section 5.1: AdamW, lr = 1e-3) with global-norm
//! gradient clipping, plus a plain SGD baseline used by ablation benches.
//!
//! Runs in rust on the L3 hot path so the AOT artifacts stay pure functions;
//! the math is bit-checked against a jnp oracle in the integration tests.

use crate::model::params::ParamSet;

#[derive(Debug, Clone, Copy)]
pub struct AdamWConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    /// Global-norm clip threshold (0 disables).
    pub grad_clip: f64,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 1e-4,
            grad_clip: 10.0,
        }
    }
}

/// Serializable snapshot of an [`AdamW`] optimizer's mutable state, used
/// by the checkpoint subsystem (`crate::checkpoint`). Moments are stored in
/// the same leaf order as the parameter set the optimizer was built for.
#[derive(Debug, Clone, PartialEq)]
pub struct AdamWState {
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub step: u64,
}

/// AdamW state for one parameter set (first/second moments + step count).
pub struct AdamW {
    pub cfg: AdamWConfig,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    step: u64,
}

impl AdamW {
    pub fn new(cfg: AdamWConfig, params: &ParamSet) -> AdamW {
        AdamW {
            cfg,
            m: params.tensors.iter().map(|t| vec![0.0; t.numel()]).collect(),
            v: params.tensors.iter().map(|t| vec![0.0; t.numel()]).collect(),
            step: 0,
        }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Snapshot the moments + step count (checkpoint save path).
    pub fn export_state(&self) -> AdamWState {
        AdamWState { m: self.m.clone(), v: self.v.clone(), step: self.step }
    }

    /// Restore moments + step count from a checkpoint snapshot. The state
    /// must match this optimizer's parameter structure exactly.
    pub fn load_state(&mut self, st: &AdamWState) -> anyhow::Result<()> {
        anyhow::ensure!(
            st.m.len() == self.m.len() && st.v.len() == self.v.len(),
            "optimizer state: {} moment leaves saved, {} expected",
            st.m.len(),
            self.m.len()
        );
        for (i, (m, v)) in st.m.iter().zip(&st.v).enumerate() {
            anyhow::ensure!(
                m.len() == self.m[i].len() && v.len() == self.v[i].len(),
                "optimizer state leaf {i}: {} elements saved, {} expected",
                m.len(),
                self.m[i].len()
            );
        }
        self.m = st.m.clone();
        self.v = st.v.clone();
        self.step = st.step;
        Ok(())
    }

    /// Apply one decoupled-weight-decay Adam update in place.
    /// `grads` must have identical structure to `params`.
    pub fn step(&mut self, params: &mut ParamSet, grads: &ParamSet) {
        assert_eq!(params.len(), grads.len(), "param/grad structure mismatch");
        self.step += 1;
        let t = self.step as i32;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(t);
        let bc2 = 1.0 - b2.powi(t);
        let lr = self.cfg.lr;
        let wd = self.cfg.weight_decay;
        let eps = self.cfg.eps;

        // Global-norm clip factor.
        let clip = if self.cfg.grad_clip > 0.0 {
            let norm = grads.global_norm();
            if norm > self.cfg.grad_clip {
                self.cfg.grad_clip / norm
            } else {
                1.0
            }
        } else {
            1.0
        };

        for ((p, g), (m, v)) in params
            .tensors
            .iter_mut()
            .zip(&grads.tensors)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let pv = p.as_f32_mut();
            let gv = g.as_f32();
            debug_assert_eq!(pv.len(), gv.len());
            for i in 0..pv.len() {
                let gi = gv[i] as f64 * clip;
                let mi = b1 * m[i] as f64 + (1.0 - b1) * gi;
                let vi = b2 * v[i] as f64 + (1.0 - b2) * gi * gi;
                m[i] = mi as f32;
                v[i] = vi as f32;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                let upd = mhat / (vhat.sqrt() + eps) + wd * pv[i] as f64;
                pv[i] = (pv[i] as f64 - lr * upd) as f32;
            }
        }
    }
}

/// Plain SGD (ablation baseline).
pub struct Sgd {
    pub lr: f64,
}

impl Sgd {
    pub fn step(&self, params: &mut ParamSet, grads: &ParamSet) {
        for (p, g) in params.tensors.iter_mut().zip(&grads.tensors) {
            let pv = p.as_f32_mut();
            for (x, &gx) in pv.iter_mut().zip(g.as_f32()) {
                *x -= (self.lr * gx as f64) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{Init, LeafMeta};
    use std::sync::Arc;

    fn quad_setup() -> (ParamSet, Arc<Vec<LeafMeta>>) {
        let metas = Arc::new(vec![LeafMeta {
            name: "w".into(),
            shape: vec![4],
            dtype: crate::tensor::DType::F32,
            init: Some(Init::Normal { scale: 1.0 }),
        }]);
        (ParamSet::init(&metas, 3), metas)
    }

    /// Gradient of f(w) = 0.5 * |w - target|^2 is (w - target).
    fn quad_grad(params: &ParamSet, metas: &Arc<Vec<LeafMeta>>, target: f32) -> ParamSet {
        let mut g = ParamSet::zeros_like(metas);
        let w = params.get("w").unwrap().as_f32();
        let gw = g.get_mut("w").unwrap().as_f32_mut();
        for i in 0..w.len() {
            gw[i] = w[i] - target;
        }
        g
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        let (mut params, metas) = quad_setup();
        let mut opt = AdamW::new(
            AdamWConfig { lr: 0.05, weight_decay: 0.0, ..Default::default() },
            &params,
        );
        for _ in 0..300 {
            let g = quad_grad(&params, &metas, 2.0);
            opt.step(&mut params, &g);
        }
        for &x in params.get("w").unwrap().as_f32() {
            assert!((x - 2.0).abs() < 0.05, "w={x}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let (mut params, metas) = quad_setup();
        let before = params.global_norm();
        let mut opt = AdamW::new(
            AdamWConfig { lr: 0.01, weight_decay: 0.5, grad_clip: 0.0, ..Default::default() },
            &params,
        );
        // Zero gradients: only decay acts.
        let zeros = ParamSet::zeros_like(&metas);
        for _ in 0..50 {
            opt.step(&mut params, &zeros);
        }
        assert!(params.global_norm() < before, "decay must shrink norms");
    }

    #[test]
    fn grad_clip_bounds_update() {
        let (mut params, metas) = quad_setup();
        let start = params.tensors[0].clone();
        let mut g = ParamSet::zeros_like(&metas);
        g.get_mut("w").unwrap().as_f32_mut().fill(1e6);
        let mut opt = AdamW::new(
            AdamWConfig { lr: 0.001, grad_clip: 1.0, weight_decay: 0.0, ..Default::default() },
            &params,
        );
        opt.step(&mut params, &g);
        // With clipping the first Adam step magnitude is ~lr per element.
        for (a, b) in params.tensors[0].as_f32().iter().zip(start.as_f32()) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
    }

    #[test]
    fn first_step_matches_closed_form() {
        // With m=v=0, step 1: mhat = g, vhat = g^2 -> update = lr * sign-ish.
        let metas = Arc::new(vec![LeafMeta {
            name: "w".into(),
            shape: vec![1],
            dtype: crate::tensor::DType::F32,
            init: Some(Init::Zeros),
        }]);
        let mut params = ParamSet::zeros_like(&metas);
        let mut g = ParamSet::zeros_like(&metas);
        g.get_mut("w").unwrap().as_f32_mut()[0] = 0.5;
        let cfg = AdamWConfig {
            lr: 0.1,
            weight_decay: 0.0,
            grad_clip: 0.0,
            eps: 1e-8,
            ..Default::default()
        };
        let mut opt = AdamW::new(cfg, &params);
        opt.step(&mut params, &g);
        let w = params.get("w").unwrap().as_f32()[0];
        // update = lr * g / (|g| + eps) ~ -0.1
        assert!((w + 0.1).abs() < 1e-4, "w={w}");
    }

    #[test]
    fn sgd_descends() {
        let (mut params, metas) = quad_setup();
        let sgd = Sgd { lr: 0.1 };
        let mut last = f64::INFINITY;
        for _ in 0..100 {
            let g = quad_grad(&params, &metas, -1.0);
            sgd.step(&mut params, &g);
            let loss: f64 = params
                .get("w")
                .unwrap()
                .as_f32()
                .iter()
                .map(|&x| 0.5 * ((x + 1.0) as f64).powi(2))
                .sum();
            assert!(loss <= last + 1e-9);
            last = loss;
        }
        assert!(last < 1e-3);
    }
}
