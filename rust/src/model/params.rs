//! Parameter storage: named, ordered tensors matching the AOT manifest.
//!
//! The manifest records the flattened pytree order of the jax parameters
//! (`branch.*` then `encoder.*`, dict-key sorted); the rust side initializes
//! tensors of the same shapes with the initializer hints the manifest
//! carries, so no jax is needed at run time.

use std::collections::HashMap;
use std::sync::Arc;

use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Initializer hint from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub enum Init {
    Lecun { fan_in: usize },
    Normal { scale: f64 },
    Zeros,
}

/// Metadata for one parameter / batch-field / output leaf.
#[derive(Debug, Clone, PartialEq)]
pub struct LeafMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: crate::tensor::DType,
    pub init: Option<Init>,
}

impl LeafMeta {
    pub fn from_json(j: &Json) -> anyhow::Result<LeafMeta> {
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("leaf missing name"))?
            .to_string();
        let shape: Vec<usize> = j
            .get("shape")
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("leaf {name} missing shape"))?
            .iter()
            .map(|v| v.as_i64().unwrap_or(0) as usize)
            .collect();
        let dtype = crate::tensor::DType::parse(
            j.get("dtype").as_str().unwrap_or("float32"),
        )?;
        let init = match j.get("init").get("kind").as_str() {
            Some("lecun") => Some(Init::Lecun {
                fan_in: j.get("init").get("fan_in").as_i64().unwrap_or(1) as usize,
            }),
            Some("normal") => Some(Init::Normal {
                scale: j.get("init").get("scale").as_f64().unwrap_or(1.0),
            }),
            Some("zeros") => Some(Init::Zeros),
            _ => None,
        };
        Ok(LeafMeta { name, shape, dtype, init })
    }

    pub fn numel(&self) -> usize {
        crate::tensor::numel(&self.shape)
    }
}

/// An ordered set of named f32 tensors (parameters, gradients, or moments).
#[derive(Debug, Clone)]
pub struct ParamSet {
    metas: Arc<Vec<LeafMeta>>,
    index: Arc<HashMap<String, usize>>,
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    /// Initialize parameters per the manifest's initializer hints.
    pub fn init(metas: &Arc<Vec<LeafMeta>>, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed ^ 0x9a7a_a31);
        let tensors = metas
            .iter()
            .map(|m| {
                let n = m.numel();
                let data: Vec<f32> = match &m.init {
                    Some(Init::Lecun { fan_in }) => {
                        let std = 1.0 / (*fan_in as f64).sqrt();
                        (0..n).map(|_| rng.normal_scaled(0.0, std) as f32).collect()
                    }
                    Some(Init::Normal { scale }) => {
                        (0..n).map(|_| rng.normal_scaled(0.0, *scale) as f32).collect()
                    }
                    Some(Init::Zeros) | None => vec![0.0; n],
                };
                Tensor::from_f32(&m.shape, data)
            })
            .collect();
        ParamSet { metas: Arc::clone(metas), index: Self::build_index(metas), tensors }
    }

    /// All-zero set with the same structure (gradient / moment buffers).
    pub fn zeros_like(metas: &Arc<Vec<LeafMeta>>) -> ParamSet {
        let tensors = metas.iter().map(|m| Tensor::zeros(&m.shape)).collect();
        ParamSet { metas: Arc::clone(metas), index: Self::build_index(metas), tensors }
    }

    fn build_index(metas: &Arc<Vec<LeafMeta>>) -> Arc<HashMap<String, usize>> {
        Arc::new(
            metas
                .iter()
                .enumerate()
                .map(|(i, m)| (m.name.clone(), i))
                .collect(),
        )
    }

    pub fn metas(&self) -> &[LeafMeta] {
        &self.metas
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn total_params(&self) -> usize {
        self.metas.iter().map(|m| m.numel()).sum()
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        let i = *self.index.get(name)?;
        Some(&mut self.tensors[i])
    }

    /// Iterate (name, tensor).
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.metas.iter().zip(&self.tensors).map(|(m, t)| (m.name.as_str(), t))
    }

    /// Flatten all values into one contiguous f32 vec (collective payload).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_params());
        for t in &self.tensors {
            out.extend_from_slice(t.as_f32());
        }
        out
    }

    /// Load values back from a flat vec produced by `flatten()`.
    pub fn unflatten_from(&mut self, flat: &[f32]) {
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.numel();
            t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len(), "flat buffer size mismatch");
    }

    /// Flatten only leaves whose name starts with `prefix` into `out`
    /// (cleared first). Allocation-free on the steady state — the trainer's
    /// per-step gradient-sync path uses this instead of `subset().flatten()`
    /// which would clone every tensor.
    pub fn flatten_prefix_into(&self, prefix: &str, out: &mut Vec<f32>) {
        out.clear();
        for (m, t) in self.metas.iter().zip(&self.tensors) {
            if m.name.starts_with(prefix) {
                out.extend_from_slice(t.as_f32());
            }
        }
    }

    /// Scatter a flat buffer produced by `flatten_prefix_into` back into the
    /// matching leaves.
    pub fn unflatten_prefix_from(&mut self, prefix: &str, flat: &[f32]) {
        let mut off = 0;
        for (m, t) in self.metas.iter().zip(self.tensors.iter_mut()) {
            if m.name.starts_with(prefix) {
                let n = t.numel();
                t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
                off += n;
            }
        }
        assert_eq!(off, flat.len(), "flat buffer size mismatch for '{prefix}'");
    }

    /// Global L2 norm over every tensor.
    pub fn global_norm(&self) -> f64 {
        self.tensors
            .iter()
            .map(|t| t.as_f32().iter().map(|&x| (x as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            .sqrt()
    }

    /// Sub-set of leaves whose name starts with `prefix` (e.g. "encoder.").
    /// Metas keep their full names so engine marshalling stays name-driven.
    pub fn subset(&self, prefix: &str) -> ParamSet {
        let pairs: Vec<(LeafMeta, Tensor)> = self
            .metas
            .iter()
            .zip(&self.tensors)
            .filter(|(m, _)| m.name.starts_with(prefix))
            .map(|(m, t)| (m.clone(), t.clone()))
            .collect();
        let metas = Arc::new(pairs.iter().map(|(m, _)| m.clone()).collect::<Vec<_>>());
        let tensors = pairs.into_iter().map(|(_, t)| t).collect();
        ParamSet { index: Self::build_index(&metas), metas, tensors }
    }

    /// Copy values for shared names from `other` into self.
    pub fn copy_matching_from(&mut self, other: &ParamSet) {
        for (name, src) in other.iter() {
            if let Some(dst) = self.get_mut(name) {
                dst.as_f32_mut().copy_from_slice(src.as_f32());
            }
        }
    }

    /// Rebuild a set from explicit metas + tensors (the checkpoint load
    /// path). Every tensor must match its meta's shape and dtype.
    pub fn from_parts(metas: Vec<LeafMeta>, tensors: Vec<Tensor>) -> anyhow::Result<ParamSet> {
        anyhow::ensure!(
            metas.len() == tensors.len(),
            "param set: {} metas vs {} tensors",
            metas.len(),
            tensors.len()
        );
        for (m, t) in metas.iter().zip(&tensors) {
            anyhow::ensure!(
                t.shape == m.shape,
                "leaf {}: tensor shape {:?} does not match meta shape {:?}",
                m.name,
                t.shape,
                m.shape
            );
            anyhow::ensure!(t.dtype() == m.dtype, "leaf {}: dtype mismatch", m.name);
        }
        let metas = Arc::new(metas);
        Ok(ParamSet { index: Self::build_index(&metas), metas, tensors })
    }

    /// True when `other` has identical leaf names and shapes, in the same
    /// order (checkpoint/engine compatibility check before values are
    /// copied across).
    pub fn same_structure(&self, other: &ParamSet) -> bool {
        self.metas.len() == other.metas.len()
            && self
                .metas
                .iter()
                .zip(other.metas.iter())
                .all(|(a, b)| a.name == b.name && a.shape == b.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas() -> Arc<Vec<LeafMeta>> {
        Arc::new(vec![
            LeafMeta {
                name: "branch.trunk.w1".into(),
                shape: vec![4, 8],
                dtype: crate::tensor::DType::F32,
                init: Some(Init::Lecun { fan_in: 4 }),
            },
            LeafMeta {
                name: "branch.trunk.b1".into(),
                shape: vec![8],
                dtype: crate::tensor::DType::F32,
                init: Some(Init::Zeros),
            },
            LeafMeta {
                name: "encoder.embed".into(),
                shape: vec![10, 8],
                dtype: crate::tensor::DType::F32,
                init: Some(Init::Normal { scale: 0.5 }),
            },
        ])
    }

    #[test]
    fn init_respects_hints() {
        let p = ParamSet::init(&metas(), 1);
        assert_eq!(p.total_params(), 4 * 8 + 8 + 80);
        assert!(p.get("branch.trunk.b1").unwrap().as_f32().iter().all(|&x| x == 0.0));
        assert!(p.get("branch.trunk.w1").unwrap().norm() > 0.0);
        // Lecun std ~ 0.5 for fan_in 4; embed scale 0.5: both nonzero.
        assert!(p.get("encoder.embed").unwrap().norm() > 0.0);
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let a = ParamSet::init(&metas(), 42);
        let b = ParamSet::init(&metas(), 42);
        let c = ParamSet::init(&metas(), 43);
        assert_eq!(a.tensors, b.tensors);
        assert_ne!(a.tensors, c.tensors);
    }

    #[test]
    fn flatten_roundtrip() {
        let p = ParamSet::init(&metas(), 3);
        let flat = p.flatten();
        assert_eq!(flat.len(), p.total_params());
        let mut q = ParamSet::zeros_like(&Arc::new(p.metas().to_vec()));
        q.unflatten_from(&flat);
        assert_eq!(p.tensors, q.tensors);
    }

    #[test]
    fn subset_by_prefix() {
        let p = ParamSet::init(&metas(), 5);
        let enc = p.subset("encoder.");
        assert_eq!(enc.len(), 1);
        assert_eq!(enc.metas()[0].name, "encoder.embed");
        let br = p.subset("branch.");
        assert_eq!(br.len(), 2);
    }

    #[test]
    fn copy_matching() {
        let a = ParamSet::init(&metas(), 1);
        let mut b = ParamSet::init(&metas(), 2);
        b.copy_matching_from(&a.subset("encoder."));
        assert_eq!(
            b.get("encoder.embed").unwrap().as_f32(),
            a.get("encoder.embed").unwrap().as_f32()
        );
        assert_ne!(
            b.get("branch.trunk.w1").unwrap().as_f32(),
            a.get("branch.trunk.w1").unwrap().as_f32()
        );
    }

    #[test]
    fn flatten_prefix_matches_subset_flatten() {
        let p = ParamSet::init(&metas(), 8);
        let mut buf = Vec::new();
        p.flatten_prefix_into("branch.", &mut buf);
        assert_eq!(buf, p.subset("branch.").flatten());
        // Roundtrip back into a zeroed set.
        let mut q = ParamSet::zeros_like(&Arc::new(p.metas().to_vec()));
        q.unflatten_prefix_from("branch.", &buf);
        assert_eq!(
            q.get("branch.trunk.w1").unwrap().as_f32(),
            p.get("branch.trunk.w1").unwrap().as_f32()
        );
        assert!(q.get("encoder.embed").unwrap().as_f32().iter().all(|&x| x == 0.0));
        // Reuse without reallocation.
        let cap = buf.capacity();
        p.flatten_prefix_into("branch.", &mut buf);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn leaf_meta_parses_manifest_json() {
        let j = Json::parse(
            r#"{"name": "encoder.embed", "shape": [96, 64], "dtype": "float32",
                "init": {"kind": "normal", "scale": 0.5}}"#,
        )
        .unwrap();
        let m = LeafMeta::from_json(&j).unwrap();
        assert_eq!(m.name, "encoder.embed");
        assert_eq!(m.shape, vec![96, 64]);
        assert_eq!(m.init, Some(Init::Normal { scale: 0.5 }));
    }
}
