//! Model-side L3 state: named parameter sets matching the AOT manifest,
//! the AdamW optimizer, the native compute microkernels (f64 oracle +
//! blocked mixed-f32 paths), and architecture accounting (P_s / P_h
//! formulas, memory model, parallelization regimes).

pub mod arch;
pub mod egnn;
pub mod graphpar;
pub mod kernels;
pub mod optimizer;
pub mod params;

pub use arch::{ArchDims, ParallelismRegime};
pub use graphpar::{GpOut, GpPlan, GpStructure, GradLayout};
pub use kernels::Precision;
pub use optimizer::{AdamW, AdamWConfig, AdamWState, Sgd};
pub use params::{Init, LeafMeta, ParamSet};
