//! Architecture accounting: exact parameter-count formulas for the
//! HydraGNN-style EGNN encoder and MTL branches, the per-GPU memory model,
//! and the paper's three parallelization regimes (Section 4.3):
//!
//!   Case 1: P_s >> N_h * P_h  -> pipeline/tensor parallelism preferred
//!   Case 2: P_s << N_h * P_h  -> multi-task parallelism optimal
//!   Case 3: P_s ~  N_h * P_h  -> hybrid schemes
//!
//! These formulas drive the scaling simulator's communication volumes and
//! are validated against the actual manifest parameter counts in tests.

/// Model dimensions (mirrors python ModelConfig; defaults = artifact config).
#[derive(Debug, Clone, Copy)]
pub struct ArchDims {
    pub num_species: usize,
    pub hidden: usize,
    pub num_layers: usize,
    pub num_rbf: usize,
    pub head_hidden: usize,
}

impl ArchDims {
    /// The paper's published configuration (Section 5): 4-layer EGNN with
    /// 866 hidden units, heads of three 889-unit FC layers.
    pub fn paper() -> ArchDims {
        ArchDims {
            num_species: 96,
            hidden: 866,
            num_layers: 4,
            num_rbf: 16,
            head_hidden: 889,
        }
    }

    /// Shared (encoder) parameter count P_s.
    pub fn shared_params(&self) -> usize {
        let h = self.hidden;
        let r = self.num_rbf;
        let embed = self.num_species * h;
        // Per EGNN layer: edge MLP (2H+R -> H -> H), gate (H -> 1),
        // node MLP (2H -> H -> H); weights + biases.
        let edge = (2 * h + r) * h + h + h * h + h;
        let gate = h + 1;
        let node = (2 * h) * h + h + h * h + h;
        embed + self.num_layers * (edge + gate + node)
    }

    /// Per-branch (head) parameter count P_h: 3 FC layers + two sub-heads.
    pub fn head_params(&self) -> usize {
        let h = self.hidden;
        let d = self.head_hidden;
        let trunk = h * d + d + d * d + d + d * d + d;
        let energy = d + 1;
        let force = d + 1;
        trunk + energy + force
    }

    /// Total parameters for an `n_heads`-branch model on one process.
    pub fn total_params(&self, n_heads: usize) -> usize {
        self.shared_params() + n_heads * self.head_params()
    }
}

/// Bytes per parameter during training: weight + gradient + AdamW m and v,
/// all f32 (activation memory is batch-dependent and excluded, as in the
/// paper's P_s/P_h discussion).
pub const TRAIN_BYTES_PER_PARAM: usize = 16;

/// Per-GPU parameter memory without multi-task parallelism:
/// the full model `P_s + N_h * P_h` is replicated on every rank.
pub fn memory_without_mtp(dims: &ArchDims, n_heads: usize) -> usize {
    dims.total_params(n_heads) * TRAIN_BYTES_PER_PARAM
}

/// Per-GPU parameter memory with multi-task parallelism:
/// each rank holds `P_s + P_h` (one head).
pub fn memory_with_mtp(dims: &ArchDims) -> usize {
    (dims.shared_params() + dims.head_params()) * TRAIN_BYTES_PER_PARAM
}

/// The paper's three regimes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelismRegime {
    /// Case 1: shared layers dominate -> pipeline/tensor parallelism.
    PipelineTensor,
    /// Case 2: heads dominate -> multi-task parallelism.
    MultiTask,
    /// Case 3: comparable -> hybrid.
    Hybrid,
}

/// Classify with a factor-of-`threshold` band around parity (paper uses
/// ">>" / "<<" informally; 4x is a reasonable reading).
pub fn classify_regime(dims: &ArchDims, n_heads: usize, threshold: f64) -> ParallelismRegime {
    let ps = dims.shared_params() as f64;
    let ph_total = (n_heads * dims.head_params()) as f64;
    if ps > threshold * ph_total {
        ParallelismRegime::PipelineTensor
    } else if ph_total > threshold * ps {
        ParallelismRegime::MultiTask
    } else {
        ParallelismRegime::Hybrid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dims() -> ArchDims {
        // Matches python ModelConfig defaults lowered into artifacts/.
        ArchDims { num_species: 96, hidden: 64, num_layers: 4, num_rbf: 16, head_hidden: 64 }
    }

    #[test]
    fn shared_params_formula() {
        let d = artifact_dims();
        // embed 96*64=6144; per layer: edge (144*64 + 64 + 64*64 + 64),
        // gate (64+1), node (128*64 + 64 + 64*64 + 64).
        let per_layer = 144 * 64 + 64 + 64 * 64 + 64 + 65 + 128 * 64 + 64 + 64 * 64 + 64;
        assert_eq!(d.shared_params(), 6144 + 4 * per_layer);
    }

    #[test]
    fn head_params_formula() {
        let d = artifact_dims();
        let expected = 64 * 64 + 64 + 64 * 64 + 64 + 64 * 64 + 64 + 65 + 65;
        assert_eq!(d.head_params(), expected);
    }

    #[test]
    fn mtp_memory_saves_for_many_heads() {
        let d = ArchDims::paper();
        let without = memory_without_mtp(&d, 5);
        let with = memory_with_mtp(&d);
        assert!(with < without);
        // Savings ratio approaches (P_s + P_h) / (P_s + 5 P_h).
        let ratio = with as f64 / without as f64;
        assert!(ratio < 0.75, "ratio={ratio}");
    }

    #[test]
    fn paper_config_param_scale() {
        // Sanity: paper-scale model is tens of millions of parameters.
        let d = ArchDims::paper();
        let total = d.total_params(5);
        assert!(total > 10_000_000, "{total}");
        assert!(total < 100_000_000, "{total}");
    }

    #[test]
    fn regimes_classify_as_paper_argues() {
        // GNNs with many heads fall in Case 2 (paper Section 4.3): scale the
        // head count up and the classification must flip to MultiTask.
        let d = ArchDims::paper();
        assert_eq!(classify_regime(&d, 50, 4.0), ParallelismRegime::MultiTask);
        // A single modest head on a huge encoder is Case 1.
        let wide = ArchDims { hidden: 4096, head_hidden: 64, ..ArchDims::paper() };
        assert_eq!(classify_regime(&wide, 1, 4.0), ParallelismRegime::PipelineTensor);
        // Comparable sizes -> hybrid.
        let mid = ArchDims { hidden: 256, head_hidden: 256, ..ArchDims::paper() };
        let n = {
            // pick n_heads so n*P_h is within 4x of P_s
            let ps = mid.shared_params() as f64;
            (ps / mid.head_params() as f64).round() as usize
        };
        assert_eq!(classify_regime(&mid, n.max(1), 4.0), ParallelismRegime::Hybrid);
    }
}
