//! Compute microkernels for the native EGNN engine, at two precisions.
//!
//! The native backend's hot spots are dense row-major matmuls over padded
//! batch buffers (`out = x @ w + b` in the forward, `x^T @ dy` / `dy @ w^T`
//! in the analytic backward) plus the silu/tanh elementwise passes between
//! them. This module holds both compute paths behind the [`Precision`]
//! knob:
//!
//! * **`Precision::F64`** (default) — the scalar f64 kernels, moved here
//!   verbatim from `model::egnn`. This path is the numerical oracle: its
//!   results are kept byte-for-byte stable (the gradcheck finite-difference
//!   harness and the checkpoint bit-parity tests pin it).
//! * **`Precision::MixedF32`** — blocked, autovectorizable f32 microkernels
//!   with **f64 accumulators**: inputs and weights are downcast to f32 once
//!   per call, products are computed in f32 and accumulated in f64 register
//!   blocks ([`COL_BLOCK`] output columns at a time), mirroring the
//!   reduced-precision-compute / full-precision-accumulate recipe the
//!   HydraGNN-lineage GFM training runs use at scale. The fused
//!   [`linear_silu_into_mixed`] pass additionally applies the silu
//!   activation while the output block is still in registers.
//!
//! **Determinism contract:** for every kernel, the per-output-element
//! accumulation order is a function of the shapes only — row chunking
//! (across worker threads) and column blocking never reorder a reduction.
//! Results are therefore bit-identical for any thread count at a fixed
//! precision, which is what keeps the reproducibility and checkpoint
//! kill-at-k parity guarantees intact on both paths (proven in the tests
//! below and in `rust/tests/integration_precision.rs`).
//!
//! Worker fan-out follows `plan_threads`: large kernels split over row (or
//! gradient-column) chunks, capped at [`thread_cap`] workers — the
//! `HYDRA_MTP_THREADS` environment variable overrides the default cap of
//! 8 (clamped to `[1, 512]`; `0` means serial).

// ---------------------------------------------------------------------------
// precision knob
// ---------------------------------------------------------------------------

/// Numeric precision of the native backend's compute kernels. Selected via
/// `RunConfig.precision`, CLI `--precision f64|mixed-f32`, or the
/// `HYDRA_MTP_PRECISION` environment variable (a CI-matrix override that
/// wins over the config wherever a precision is resolved from one — see
/// [`Precision::resolve`]). The PJRT backend ignores it: its numerics are
/// fixed by the compiled artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Scalar f64 compute everywhere — the gradcheck oracle (default).
    #[default]
    F64,
    /// Blocked f32 compute with f64 accumulation in the matmul and
    /// silu/gate kernels; f64 everywhere else (loss reduction, scatter
    /// aggregation, optimizer).
    MixedF32,
}

impl Precision {
    pub fn parse(s: &str) -> anyhow::Result<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "full" => Ok(Precision::F64),
            "mixed-f32" | "mixed_f32" | "mixedf32" | "f32" => Ok(Precision::MixedF32),
            other => anyhow::bail!("unknown precision '{other}' (expected f64|mixed-f32)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::MixedF32 => "mixed-f32",
        }
    }

    /// The `HYDRA_MTP_PRECISION` environment override, if set. An invalid
    /// value warns and is ignored rather than poisoning every engine load.
    pub fn from_env() -> Option<Precision> {
        match std::env::var("HYDRA_MTP_PRECISION") {
            Ok(v) if !v.is_empty() => match Precision::parse(&v) {
                Ok(p) => Some(p),
                Err(e) => {
                    eprintln!("warning: HYDRA_MTP_PRECISION ignored: {e}");
                    None
                }
            },
            _ => None,
        }
    }

    /// Resolve a configured precision against the environment: the
    /// `HYDRA_MTP_PRECISION` override wins when present (so a CI matrix leg
    /// can re-run the whole suite at mixed precision without touching any
    /// config), otherwise `self` is used as-is. Unlike `HYDRA_MTP_BACKEND`
    /// (which only applies to `BackendKind::Auto`), the two-variant knob
    /// has no "auto" sentinel, so an override that disagrees with the
    /// configured value is at least made LOUD rather than silently winning.
    /// Callers that must pin an exact precision (the gradcheck oracle, the
    /// per-precision parity tests, the side-by-side bench) bypass this and
    /// construct engines with an explicit value.
    pub fn resolve(self) -> Precision {
        match Precision::from_env() {
            Some(p) => {
                if p != self {
                    eprintln!(
                        "warning: HYDRA_MTP_PRECISION={} overrides the configured \
                         precision {}",
                        p.name(),
                        self.name()
                    );
                }
                p
            }
            None => self,
        }
    }
}

// ---------------------------------------------------------------------------
// thread planning
// ---------------------------------------------------------------------------

/// Default worker cap when `HYDRA_MTP_THREADS` is unset or unparseable.
pub const DEFAULT_THREAD_CAP: usize = 8;
/// Hard ceiling on the worker cap (a larger env value is clamped here).
pub const MAX_THREAD_CAP: usize = 512;

/// The kernel worker cap: `HYDRA_MTP_THREADS` when set, else
/// [`DEFAULT_THREAD_CAP`]. See [`thread_cap_from`] for the clamping rules.
/// Read from the environment once per process (the hot path calls this on
/// every above-threshold kernel; nothing mutates the variable mid-run).
pub fn thread_cap() -> usize {
    static CAP: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CAP.get_or_init(|| thread_cap_from(std::env::var("HYDRA_MTP_THREADS").ok().as_deref()))
}

/// Pure core of [`thread_cap`], testable without touching the process
/// environment: `None`/empty/garbage -> [`DEFAULT_THREAD_CAP`]; `0` -> 1
/// (serial); anything larger is clamped to [`MAX_THREAD_CAP`].
pub fn thread_cap_from(raw: Option<&str>) -> usize {
    match raw.map(str::trim) {
        Some(s) if !s.is_empty() => match s.parse::<usize>() {
            Ok(0) => 1,
            Ok(v) => v.min(MAX_THREAD_CAP),
            Err(_) => DEFAULT_THREAD_CAP,
        },
        _ => DEFAULT_THREAD_CAP,
    }
}

/// Worker count for a kernel of `work` multiply-adds spread over `rows`
/// independent rows. Small kernels stay serial (thread spawn would
/// dominate); large ones fan out like `FeaturizedStore::build`. Chunking
/// never alters per-row accumulation order, so the result is
/// thread-count independent.
pub fn plan_threads(rows: usize, work: usize) -> usize {
    if work < 2 * WORK_PER_THREAD || rows < 2 {
        return 1; // small kernel: stay serial without touching env/sysinfo
    }
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    plan_threads_with(rows, work, avail, thread_cap())
}

const WORK_PER_THREAD: usize = 1 << 21; // ~2M multiply-adds

/// Pure core of [`plan_threads`]: `avail` is the machine parallelism,
/// `cap` the configured worker ceiling (see [`thread_cap`]).
pub fn plan_threads_with(rows: usize, work: usize, avail: usize, cap: usize) -> usize {
    if work < 2 * WORK_PER_THREAD || rows < 2 {
        return 1;
    }
    (work / WORK_PER_THREAD).clamp(1, avail.max(1).min(cap.max(1)).min(rows))
}

// ---------------------------------------------------------------------------
// f64 reference kernels (the oracle path; byte-for-byte stable)
// ---------------------------------------------------------------------------

/// Row block of `out[m,n] = x[m,k] @ w[k,n] + b[n]` in scalar f64.
pub fn linear_rows(x: &[f64], w: &[f64], b: &[f64], out: &mut [f64], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    for i in 0..rows {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        orow.copy_from_slice(b);
        for (kk, &a) in xrow.iter().enumerate() {
            if a != 0.0 {
                let wrow = &w[kk * n..(kk + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += a * wv;
                }
            }
        }
    }
}

/// out[m,n] = x[m,k] @ w[k,n] + b[n], parallel over row chunks.
pub fn linear_into(x: &[f64], w: &[f64], b: &[f64], out: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    let threads = plan_threads(m, m * k * n);
    if threads <= 1 || k == 0 || n == 0 {
        linear_rows(x, w, b, out, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (x_chunk, out_chunk) in x.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n)) {
            scope.spawn(move || linear_rows(x_chunk, w, b, out_chunk, k, n));
        }
    });
}

/// One column block of gw += x^T @ dy: `gw_chunk` covers columns
/// `k0..k0+kw` of x. Accumulates over `m` in order for any chunking.
fn grad_w_block(
    x: &[f64],
    dy: &[f64],
    gw_chunk: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    k0: usize,
) {
    if n == 0 {
        return;
    }
    let kw = gw_chunk.len() / n;
    for mi in 0..m {
        let dyrow = &dy[mi * n..(mi + 1) * n];
        let xrow = &x[mi * k..(mi + 1) * k];
        for kk in 0..kw {
            let a = xrow[k0 + kk];
            if a != 0.0 {
                let grow = &mut gw_chunk[kk * n..(kk + 1) * n];
                for (gv, &dv) in grow.iter_mut().zip(dyrow) {
                    *gv += a * dv;
                }
            }
        }
    }
}

/// gw[k,n] += x[m,k]^T @ dy[m,n], parallel over column chunks of x (= row
/// chunks of gw).
pub fn grad_w_into(x: &[f64], dy: &[f64], gw: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(gw.len(), k * n);
    let threads = plan_threads(k, m * k * n);
    if threads <= 1 || n == 0 {
        grad_w_block(x, dy, gw, m, k, n, 0);
        return;
    }
    let cols_per = k.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, gw_chunk) in gw.chunks_mut(cols_per * n).enumerate() {
            scope.spawn(move || grad_w_block(x, dy, gw_chunk, m, k, n, t * cols_per));
        }
    });
}

/// Row block of dx += dy @ w^T.
fn grad_x_rows(dy: &[f64], w: &[f64], dx: &mut [f64], k: usize, n: usize) {
    if k == 0 {
        return;
    }
    let rows = dx.len() / k;
    for i in 0..rows {
        let dyrow = &dy[i * n..(i + 1) * n];
        let dxrow = &mut dx[i * k..(i + 1) * k];
        for (kk, dv) in dxrow.iter_mut().enumerate() {
            *dv += dot(dyrow, &w[kk * n..(kk + 1) * n]);
        }
    }
}

/// dx[m,k] += dy[m,n] @ w[k,n]^T, parallel over row chunks.
pub fn grad_x_into(dy: &[f64], w: &[f64], dx: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    let threads = plan_threads(m, m * k * n);
    if threads <= 1 || k == 0 || n == 0 {
        grad_x_rows(dy, w, dx, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (dy_chunk, dx_chunk) in dy.chunks(rows_per * n).zip(dx.chunks_mut(rows_per * k)) {
            scope.spawn(move || grad_x_rows(dy_chunk, w, dx_chunk, k, n));
        }
    });
}

/// Dot product in f64 (the oracle twin of [`dot_mixed`]).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// silu in f64 (the oracle twin of [`silu_mixed`]).
#[inline]
pub fn silu(x: f64) -> f64 {
    x * sigmoid(x)
}

/// Derivative of silu wrt its pre-activation, f64 (twin of [`dsilu_mixed`]).
#[inline]
pub fn dsilu(a: f64) -> f64 {
    let s = sigmoid(a);
    s * (1.0 + a * (1.0 - s))
}

/// Elementwise silu in f64 (twin of [`map_silu_mixed`]).
pub fn map_silu(a: &[f64]) -> Vec<f64> {
    a.iter().map(|&x| silu(x)).collect()
}

/// dy * dsilu(a) elementwise, f64 (twin of [`mul_dsilu_mixed`]).
pub fn mul_dsilu(dy: &[f64], a: &[f64]) -> Vec<f64> {
    dy.iter().zip(a).map(|(&g, &x)| g * dsilu(x)).collect()
}

/// gb[n] += column sums of dy[m,n] (pure f64 addition at both precisions —
/// there are no products to quantize).
pub fn colsum_into(dy: &[f64], gb: &mut [f64], m: usize, n: usize) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(gb.len(), n);
    for mi in 0..m {
        let row = &dy[mi * n..(mi + 1) * n];
        for (g, &v) in gb.iter_mut().zip(row) {
            *g += v;
        }
    }
}

// ---------------------------------------------------------------------------
// blocked f32 microkernels (f32 products, f64 accumulators)
// ---------------------------------------------------------------------------

/// Output-column register block width of the f32 microkernels. Eight f64
/// accumulators fit two AVX2 registers (four AVX-512 / NEON pairs), and the
/// f32 product row is a single 256-bit load — the inner loop autovectorizes
/// on every target the paper's machines cover.
pub const COL_BLOCK: usize = 8;

/// Elementwise f64 -> f32 downcast. This is the ONE definition the cached
/// weight views (`EncoderParams::cache_f32` / `BranchParams::cache_f32`)
/// and the per-call mixed kernels share, so a cached view is elementwise
/// bit-identical to the downcast every uncached call performs — the
/// foundation of the serving path's bit-identity guarantee.
pub fn downcast(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// Register-blocked row block of `out[m,n] = x[m,k] @ w[k,n] + b[n]`:
/// f32 inputs/weights, f32 products, f64 accumulation (the bias is added
/// at f64). Accumulation order over `k` is fixed per output element, so
/// the result is independent of both the block width and any row chunking.
pub fn linear_rows_f32(x: &[f32], w: &[f32], b: &[f64], out: &mut [f64], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    for i in 0..rows {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let bw = COL_BLOCK.min(n - j0);
            let mut acc = [0.0f64; COL_BLOCK];
            acc[..bw].copy_from_slice(&b[j0..j0 + bw]);
            for (kk, &a) in xrow.iter().enumerate() {
                if a != 0.0 {
                    let wrow = &w[kk * n + j0..kk * n + j0 + bw];
                    for (av, &wv) in acc[..bw].iter_mut().zip(wrow) {
                        *av += (a * wv) as f64;
                    }
                }
            }
            orow[j0..j0 + bw].copy_from_slice(&acc[..bw]);
            j0 += bw;
        }
    }
}

/// Mixed-precision `out[m,n] = x[m,k] @ w[k,n] + b[n]` over f64 buffers:
/// weights are downcast once, each worker downcasts its own row chunk.
pub fn linear_into_mixed(
    x: &[f64],
    w: &[f64],
    b: &[f64],
    out: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    linear_into_mixed_threads(x, w, b, out, m, k, n, plan_threads(m, m * k * n));
}

/// [`linear_into_mixed`] with an explicit worker count (the thread-count
/// independence tests drive this directly).
#[allow(clippy::too_many_arguments)]
pub fn linear_into_mixed_threads(
    x: &[f64],
    w: &[f64],
    b: &[f64],
    out: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    let w32 = downcast(w);
    if threads <= 1 || m == 0 || k == 0 || n == 0 {
        let x32 = downcast(x);
        linear_rows_f32(&x32, &w32, b, out, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let w32 = &w32;
    std::thread::scope(|scope| {
        for (x_chunk, out_chunk) in x.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n)) {
            scope.spawn(move || {
                let x32 = downcast(x_chunk);
                linear_rows_f32(&x32, w32, b, out_chunk, k, n);
            });
        }
    });
}

/// Fused linear + silu row block: fills the f64 pre-activation (kept for
/// the backward pass) and its silu while the output block is still hot,
/// one memory pass instead of two. The silu itself is computed in f32
/// (`silu_mixed` of the accumulated f64 value), identical to running
/// [`map_silu_mixed`] over `pre` afterwards.
fn linear_rows_silu_f32(
    x: &[f32],
    w: &[f32],
    b: &[f64],
    pre: &mut [f64],
    act: &mut [f64],
    k: usize,
    n: usize,
) {
    if n == 0 {
        return;
    }
    let rows = pre.len() / n;
    for i in 0..rows {
        let xrow = &x[i * k..(i + 1) * k];
        let prow = &mut pre[i * n..(i + 1) * n];
        let arow = &mut act[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let bw = COL_BLOCK.min(n - j0);
            let mut acc = [0.0f64; COL_BLOCK];
            acc[..bw].copy_from_slice(&b[j0..j0 + bw]);
            for (kk, &a) in xrow.iter().enumerate() {
                if a != 0.0 {
                    let wrow = &w[kk * n + j0..kk * n + j0 + bw];
                    for (av, &wv) in acc[..bw].iter_mut().zip(wrow) {
                        *av += (a * wv) as f64;
                    }
                }
            }
            prow[j0..j0 + bw].copy_from_slice(&acc[..bw]);
            for (o, &v) in arow[j0..j0 + bw].iter_mut().zip(&acc[..bw]) {
                *o = silu_mixed(v);
            }
            j0 += bw;
        }
    }
}

/// Mixed-precision fused linear + silu: `pre = x @ w + b`, `act =
/// silu(pre)`, one pass. Same chunking (and therefore bit-determinism)
/// as [`linear_into_mixed`].
#[allow(clippy::too_many_arguments)]
pub fn linear_silu_into_mixed(
    x: &[f64],
    w: &[f64],
    b: &[f64],
    pre: &mut [f64],
    act: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(pre.len(), m * n);
    debug_assert_eq!(act.len(), m * n);
    let threads = plan_threads(m, m * k * n);
    let w32 = downcast(w);
    if threads <= 1 || k == 0 || n == 0 {
        let x32 = downcast(x);
        linear_rows_silu_f32(&x32, &w32, b, pre, act, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let w32 = &w32;
    std::thread::scope(|scope| {
        for ((x_chunk, pre_chunk), act_chunk) in x
            .chunks(rows_per * k)
            .zip(pre.chunks_mut(rows_per * n))
            .zip(act.chunks_mut(rows_per * n))
        {
            scope.spawn(move || {
                let x32 = downcast(x_chunk);
                linear_rows_silu_f32(&x32, w32, b, pre_chunk, act_chunk, k, n);
            });
        }
    });
}

/// [`linear_into_mixed`] against a pre-downcast weight view (`w32 =
/// downcast(w)` computed once at model load). Identical chunking and
/// accumulation order, so the result is bit-identical to the uncached
/// call — the per-invocation weight downcast is simply skipped.
#[allow(clippy::too_many_arguments)]
pub fn linear_into_mixed_w32(
    x: &[f64],
    w32: &[f32],
    b: &[f64],
    out: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w32.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    let threads = plan_threads(m, m * k * n);
    if threads <= 1 || m == 0 || k == 0 || n == 0 {
        let x32 = downcast(x);
        linear_rows_f32(&x32, w32, b, out, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (x_chunk, out_chunk) in x.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n)) {
            scope.spawn(move || {
                let x32 = downcast(x_chunk);
                linear_rows_f32(&x32, w32, b, out_chunk, k, n);
            });
        }
    });
}

/// [`linear_silu_into_mixed`] against a pre-downcast weight view. Same
/// chunking, bit-identical result, no per-call weight downcast.
#[allow(clippy::too_many_arguments)]
pub fn linear_silu_into_mixed_w32(
    x: &[f64],
    w32: &[f32],
    b: &[f64],
    pre: &mut [f64],
    act: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w32.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(pre.len(), m * n);
    debug_assert_eq!(act.len(), m * n);
    let threads = plan_threads(m, m * k * n);
    if threads <= 1 || k == 0 || n == 0 {
        let x32 = downcast(x);
        linear_rows_silu_f32(&x32, w32, b, pre, act, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for ((x_chunk, pre_chunk), act_chunk) in x
            .chunks(rows_per * k)
            .zip(pre.chunks_mut(rows_per * n))
            .zip(act.chunks_mut(rows_per * n))
        {
            scope.spawn(move || {
                let x32 = downcast(x_chunk);
                linear_rows_silu_f32(&x32, w32, b, pre_chunk, act_chunk, k, n);
            });
        }
    });
}

/// Mixed-precision column block of gw += x^T @ dy (f32 products, f64
/// accumulation over `m` in order).
fn grad_w_block_f32(
    x: &[f32],
    dy: &[f32],
    gw_chunk: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    k0: usize,
) {
    if n == 0 {
        return;
    }
    let kw = gw_chunk.len() / n;
    for mi in 0..m {
        let dyrow = &dy[mi * n..(mi + 1) * n];
        let xrow = &x[mi * k..(mi + 1) * k];
        for kk in 0..kw {
            let a = xrow[k0 + kk];
            if a != 0.0 {
                let grow = &mut gw_chunk[kk * n..(kk + 1) * n];
                for (gv, &dv) in grow.iter_mut().zip(dyrow) {
                    *gv += (a * dv) as f64;
                }
            }
        }
    }
}

/// Mixed-precision gw[k,n] += x[m,k]^T @ dy[m,n].
pub fn grad_w_into_mixed(x: &[f64], dy: &[f64], gw: &mut [f64], m: usize, k: usize, n: usize) {
    grad_w_into_mixed_threads(x, dy, gw, m, k, n, plan_threads(k, m * k * n));
}

/// [`grad_w_into_mixed`] with an explicit worker count.
pub fn grad_w_into_mixed_threads(
    x: &[f64],
    dy: &[f64],
    gw: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(gw.len(), k * n);
    let x32 = downcast(x);
    let dy32 = downcast(dy);
    if threads <= 1 || k == 0 || n == 0 {
        grad_w_block_f32(&x32, &dy32, gw, m, k, n, 0);
        return;
    }
    let cols_per = k.div_ceil(threads);
    let (x32, dy32) = (&x32, &dy32);
    std::thread::scope(|scope| {
        for (t, gw_chunk) in gw.chunks_mut(cols_per * n).enumerate() {
            scope.spawn(move || grad_w_block_f32(x32, dy32, gw_chunk, m, k, n, t * cols_per));
        }
    });
}

/// Mixed-precision row block of dx += dy @ w^T (per-element f64 dot
/// accumulator over f32 products).
fn grad_x_rows_f32(dy: &[f32], w: &[f32], dx: &mut [f64], k: usize, n: usize) {
    if k == 0 {
        return;
    }
    let rows = dx.len() / k;
    for i in 0..rows {
        let dyrow = &dy[i * n..(i + 1) * n];
        let dxrow = &mut dx[i * k..(i + 1) * k];
        for (kk, dv) in dxrow.iter_mut().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f64;
            for (&d, &wv) in dyrow.iter().zip(wrow) {
                acc += (d * wv) as f64;
            }
            *dv += acc;
        }
    }
}

/// Mixed-precision dx[m,k] += dy[m,n] @ w[k,n]^T.
pub fn grad_x_into_mixed(dy: &[f64], w: &[f64], dx: &mut [f64], m: usize, k: usize, n: usize) {
    grad_x_into_mixed_threads(dy, w, dx, m, k, n, plan_threads(m, m * k * n));
}

/// [`grad_x_into_mixed`] with an explicit worker count.
pub fn grad_x_into_mixed_threads(
    dy: &[f64],
    w: &[f64],
    dx: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    let w32 = downcast(w);
    if threads <= 1 || m == 0 || k == 0 || n == 0 {
        let dy32 = downcast(dy);
        grad_x_rows_f32(&dy32, &w32, dx, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let w32 = &w32;
    std::thread::scope(|scope| {
        for (dy_chunk, dx_chunk) in dy.chunks(rows_per * n).zip(dx.chunks_mut(rows_per * k)) {
            scope.spawn(move || {
                let dy32 = downcast(dy_chunk);
                grad_x_rows_f32(&dy32, w32, dx_chunk, k, n);
            });
        }
    });
}

// ---------------------------------------------------------------------------
// f32 elementwise / reduction passes (the silu / gate hot spots)
// ---------------------------------------------------------------------------

/// Dot product with f32 products and an f64 accumulator (the tanh-gate and
/// sub-head reductions).
pub fn dot_mixed(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x as f32 * y as f32) as f64).sum()
}

/// [`dot_mixed`] against a pre-downcast right-hand side (`b32 =
/// downcast(b)`); bit-identical, no per-call downcast of the weights.
pub fn dot_mixed_w32(a: &[f64], b32: &[f32]) -> f64 {
    a.iter().zip(b32).map(|(&x, &y)| (x as f32 * y) as f64).sum()
}

#[inline]
fn sigmoid_f32(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// silu computed in f32 (input/output carried in f64 buffers).
#[inline]
pub fn silu_mixed(x: f64) -> f64 {
    let a = x as f32;
    (a * sigmoid_f32(a)) as f64
}

/// Derivative of silu wrt its pre-activation, computed in f32.
#[inline]
pub fn dsilu_mixed(x: f64) -> f64 {
    let a = x as f32;
    let s = sigmoid_f32(a);
    (s * (1.0 + a * (1.0 - s))) as f64
}

/// tanh computed in f32.
#[inline]
pub fn tanh_mixed(x: f64) -> f64 {
    (x as f32).tanh() as f64
}

/// Elementwise silu in f32 over an f64 buffer.
pub fn map_silu_mixed(a: &[f64]) -> Vec<f64> {
    a.iter().map(|&x| silu_mixed(x)).collect()
}

/// dy * dsilu(a) elementwise, f32 products.
pub fn mul_dsilu_mixed(dy: &[f64], a: &[f64]) -> Vec<f64> {
    dy.iter()
        .zip(a)
        .map(|(&g, &x)| (g as f32 * dsilu_mixed(x) as f32) as f64)
        .collect()
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive f64 matmul oracle for the property tests.
    fn naive_linear(x: &[f64], w: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = b[j];
                for kk in 0..k {
                    acc += x[i * k + kk] * w[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn pseudo(vals: usize, scale: f64, phase: u64) -> Vec<f64> {
        // Deterministic, sign-mixing pseudo-random values in ~[-scale, scale].
        (0..vals)
            .map(|i| {
                let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(phase);
                let u = ((h >> 11) as f64) / ((1u64 << 53) as f64);
                (2.0 * u - 1.0) * scale
            })
            .collect()
    }

    #[test]
    fn precision_parses_and_names_roundtrip() {
        for p in [Precision::F64, Precision::MixedF32] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
        }
        assert_eq!(Precision::parse("MIXED-F32").unwrap(), Precision::MixedF32);
        assert_eq!(Precision::parse("f32").unwrap(), Precision::MixedF32);
        assert!(Precision::parse("bf16").is_err());
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn thread_cap_clamps_env_values_sanely() {
        assert_eq!(thread_cap_from(None), DEFAULT_THREAD_CAP);
        assert_eq!(thread_cap_from(Some("")), DEFAULT_THREAD_CAP);
        assert_eq!(thread_cap_from(Some("garbage")), DEFAULT_THREAD_CAP);
        assert_eq!(thread_cap_from(Some("-3")), DEFAULT_THREAD_CAP);
        assert_eq!(thread_cap_from(Some("0")), 1, "0 means serial, not panic");
        assert_eq!(thread_cap_from(Some("1")), 1);
        assert_eq!(thread_cap_from(Some(" 24 ")), 24, "whitespace tolerated");
        assert_eq!(thread_cap_from(Some("64")), 64, "cap above the old hard-wired 8");
        assert_eq!(thread_cap_from(Some("1000000")), MAX_THREAD_CAP);
    }

    #[test]
    fn plan_threads_respects_cap_rows_and_availability() {
        let big_work = 1 << 30;
        // Small work or a single row stays serial regardless of cap.
        assert_eq!(plan_threads_with(4096, 1 << 10, 64, 64), 1);
        assert_eq!(plan_threads_with(1, big_work, 64, 64), 1);
        // Large work is bounded by cap, availability, and row count.
        assert_eq!(plan_threads_with(4096, big_work, 64, 8), 8);
        assert_eq!(plan_threads_with(4096, big_work, 4, 64), 4);
        assert_eq!(plan_threads_with(3, big_work, 64, 64), 3);
        // The configurable cap actually raises the old hard-wired 8.
        assert_eq!(plan_threads_with(4096, big_work, 64, 32), 32);
        // Degenerate cap/availability values cannot panic the clamp.
        assert_eq!(plan_threads_with(4096, big_work, 0, 0), 1);
    }

    #[test]
    fn threaded_linear_matches_serial() {
        // Big enough to engage the thread fan-out (work above the
        // plan_threads threshold); must be bit-identical to serial.
        let (m, k, n) = (2048, 96, 64);
        let x: Vec<f64> = (0..m * k).map(|i| ((i * 37 % 101) as f64 - 50.0) / 17.0).collect();
        let w: Vec<f64> = (0..k * n).map(|i| ((i * 53 % 89) as f64 - 44.0) / 23.0).collect();
        let b: Vec<f64> = (0..n).map(|i| i as f64 / 7.0).collect();
        let mut serial = vec![0.0; m * n];
        linear_rows(&x, &w, &b, &mut serial, k, n);
        let mut parallel = vec![0.0; m * n];
        linear_into(&x, &w, &b, &mut parallel, m, k, n);
        assert_eq!(serial, parallel, "chunking must not change any bit");
    }

    #[test]
    fn grad_w_matches_naive_transpose_product() {
        let (m, k, n) = (7, 5, 3);
        let x: Vec<f64> = (0..m * k).map(|i| (i as f64).sin()).collect();
        let dy: Vec<f64> = (0..m * n).map(|i| (i as f64).cos()).collect();
        let mut gw = vec![0.0; k * n];
        grad_w_into(&x, &dy, &mut gw, m, k, n);
        for kk in 0..k {
            for nn in 0..n {
                let want: f64 = (0..m).map(|mi| x[mi * k + kk] * dy[mi * n + nn]).sum();
                assert!((gw[kk * n + nn] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn blocked_f32_linear_matches_f64_reference_on_adversarial_shapes() {
        // k=0 (bias-only), n=1 (single column), n below / at / above the
        // register block, non-multiples of COL_BLOCK everywhere.
        for &(m, k, n) in &[
            (1usize, 0usize, 1usize),
            (4, 0, 5),
            (1, 1, 1),
            (7, 5, 1),
            (3, 9, 7),
            (13, 9, 11),
            (5, 17, 8),
            (33, 17, 24),
            (11, 40, 19),
        ] {
            let x = pseudo(m * k, 2.0, 1);
            let w = pseudo(k * n, 1.5, 2);
            let b = pseudo(n, 0.5, 3);
            let want = naive_linear(&x, &w, &b, m, k, n);
            let mut got = vec![0.0; m * n];
            linear_into_mixed(&x, &w, &b, &mut got, m, k, n);
            for (i, (&g, &r)) in got.iter().zip(&want).enumerate() {
                let tol = 1e-4 * (1.0 + r.abs());
                assert!(
                    (g - r).abs() <= tol,
                    "({m},{k},{n})[{i}]: mixed {g} vs f64 {r}"
                );
            }
        }
    }

    #[test]
    fn blocked_f32_linear_survives_denormal_adjacent_inputs() {
        // Inputs straddling the f32 denormal boundary (~1.2e-38): products
        // underflow to denormals or zero in f32; the kernel must stay
        // finite and within an absolute floor of the f64 reference rather
        // than producing NaN/inf or panicking.
        let (m, k, n) = (3, 7, 5);
        let x: Vec<f64> = (0..m * k)
            .map(|i| if i % 3 == 0 { 3e-39 } else { 1e-38 * (i % 5) as f64 })
            .collect();
        let w: Vec<f64> = (0..k * n).map(|i| 2e-39 * ((i % 7) as f64 - 3.0)).collect();
        let b = vec![0.0; n];
        let want = naive_linear(&x, &w, &b, m, k, n);
        let mut got = vec![0.0; m * n];
        linear_into_mixed(&x, &w, &b, &mut got, m, k, n);
        for (i, (&g, &r)) in got.iter().zip(&want).enumerate() {
            assert!(g.is_finite(), "[{i}] not finite: {g}");
            assert!(
                (g - r).abs() <= 1e-2 * r.abs() + 1e-70,
                "[{i}]: mixed {g} vs f64 {r}"
            );
        }
    }

    #[test]
    fn mixed_kernels_are_thread_count_independent() {
        let (m, k, n) = (64, 40, 24);
        let x = pseudo(m * k, 1.0, 10);
        let w = pseudo(k * n, 1.0, 11);
        let b = pseudo(n, 1.0, 12);
        let dy = pseudo(m * n, 1.0, 13);

        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

        let mut lin1 = vec![0.0; m * n];
        linear_into_mixed_threads(&x, &w, &b, &mut lin1, m, k, n, 1);
        let mut gw1 = vec![0.0; k * n];
        grad_w_into_mixed_threads(&x, &dy, &mut gw1, m, k, n, 1);
        let mut gx1 = vec![0.0; m * k];
        grad_x_into_mixed_threads(&dy, &w, &mut gx1, m, k, n, 1);

        for threads in [2usize, 8] {
            let mut lin = vec![0.0; m * n];
            linear_into_mixed_threads(&x, &w, &b, &mut lin, m, k, n, threads);
            assert_eq!(bits(&lin1), bits(&lin), "linear @ {threads} threads");
            let mut gw = vec![0.0; k * n];
            grad_w_into_mixed_threads(&x, &dy, &mut gw, m, k, n, threads);
            assert_eq!(bits(&gw1), bits(&gw), "grad_w @ {threads} threads");
            let mut gx = vec![0.0; m * k];
            grad_x_into_mixed_threads(&dy, &w, &mut gx, m, k, n, threads);
            assert_eq!(bits(&gx1), bits(&gx), "grad_x @ {threads} threads");
        }
    }

    #[test]
    fn fused_linear_silu_matches_unfused_bitwise() {
        let (m, k, n) = (9, 13, 11);
        let x = pseudo(m * k, 1.2, 20);
        let w = pseudo(k * n, 0.8, 21);
        let b = pseudo(n, 0.3, 22);
        let mut pre_ref = vec![0.0; m * n];
        linear_into_mixed(&x, &w, &b, &mut pre_ref, m, k, n);
        let act_ref = map_silu_mixed(&pre_ref);
        let mut pre = vec![0.0; m * n];
        let mut act = vec![0.0; m * n];
        linear_silu_into_mixed(&x, &w, &b, &mut pre, &mut act, m, k, n);
        assert_eq!(pre_ref, pre, "fused pre-activation must match unfused");
        assert_eq!(act_ref, act, "fused silu must match unfused");
    }

    #[test]
    fn cached_w32_kernels_match_uncached_bitwise() {
        // The serving fast path downcasts weights once at model load and
        // reuses the f32 view; every result must be bit-identical to the
        // per-call downcast, including shapes big enough to fan out.
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (9, 13, 11), (33, 17, 24), (256, 72, 64)] {
            let x = pseudo(m * k, 1.2, 50);
            let w = pseudo(k * n, 0.8, 51);
            let b = pseudo(n, 0.3, 52);
            let w32 = downcast(&w);

            let mut lin_ref = vec![0.0; m * n];
            linear_into_mixed(&x, &w, &b, &mut lin_ref, m, k, n);
            let mut lin = vec![0.0; m * n];
            linear_into_mixed_w32(&x, &w32, &b, &mut lin, m, k, n);
            assert_eq!(lin_ref, lin, "linear ({m},{k},{n})");

            let mut pre_ref = vec![0.0; m * n];
            let mut act_ref = vec![0.0; m * n];
            linear_silu_into_mixed(&x, &w, &b, &mut pre_ref, &mut act_ref, m, k, n);
            let mut pre = vec![0.0; m * n];
            let mut act = vec![0.0; m * n];
            linear_silu_into_mixed_w32(&x, &w32, &b, &mut pre, &mut act, m, k, n);
            assert_eq!(pre_ref, pre, "fused pre ({m},{k},{n})");
            assert_eq!(act_ref, act, "fused act ({m},{k},{n})");
        }

        let a = pseudo(65, 1.0, 53);
        let v = pseudo(65, 1.0, 54);
        let d_ref = dot_mixed(&a, &v);
        let d = dot_mixed_w32(&a, &downcast(&v));
        assert_eq!(d_ref.to_bits(), d.to_bits(), "dot");
    }

    #[test]
    fn mixed_grad_kernels_match_f64_references_within_tolerance() {
        let (m, k, n) = (21, 15, 10);
        let x = pseudo(m * k, 1.0, 30);
        let w = pseudo(k * n, 1.0, 31);
        let dy = pseudo(m * n, 1.0, 32);
        let mut gw64 = vec![0.0; k * n];
        grad_w_into(&x, &dy, &mut gw64, m, k, n);
        let mut gw32 = vec![0.0; k * n];
        grad_w_into_mixed(&x, &dy, &mut gw32, m, k, n);
        for (i, (&a, &b_)) in gw64.iter().zip(&gw32).enumerate() {
            assert!((a - b_).abs() <= 1e-4 * (1.0 + a.abs()), "gw[{i}]: {a} vs {b_}");
        }
        let mut gx64 = vec![0.0; m * k];
        grad_x_into(&dy, &w, &mut gx64, m, k, n);
        let mut gx32 = vec![0.0; m * k];
        grad_x_into_mixed(&dy, &w, &mut gx32, m, k, n);
        for (i, (&a, &b_)) in gx64.iter().zip(&gx32).enumerate() {
            assert!((a - b_).abs() <= 1e-4 * (1.0 + a.abs()), "gx[{i}]: {a} vs {b_}");
        }
    }

    #[test]
    fn mixed_elementwise_tracks_f64_closely() {
        for &v in &[-4.0f64, -1.0, -1e-3, 0.0, 0.7, 2.5, 8.0] {
            let s64 = v * (1.0 / (1.0 + (-v).exp()));
            assert!((silu_mixed(v) - s64).abs() <= 1e-5 * (1.0 + s64.abs()), "silu({v})");
            assert!((tanh_mixed(v) - v.tanh()).abs() <= 1e-6, "tanh({v})");
        }
        let a = pseudo(33, 1.0, 40);
        let b = pseudo(33, 1.0, 41);
        let d64: f64 = a.iter().zip(&b).map(|(&x, &y)| x * y).sum();
        assert!((dot_mixed(&a, &b) - d64).abs() <= 1e-4 * (1.0 + d64.abs()));
    }
}
