//! Native EGNN compute core: the L2 model (python/compile/model.py)
//! re-implemented in pure rust with a hand-written analytic backward pass,
//! so the full train/eval/predict pipeline runs with **zero** compiled
//! artifacts. This is the math behind `runtime::native::NativeBackend`.
//!
//! The architecture mirrors the jax reference exactly:
//!
//! * encoder — species embedding, Gaussian RBF edge features under a cosine
//!   cutoff envelope, and `num_layers` EGNN blocks (edge MLP -> tanh gate ->
//!   degree-normalized scatter aggregation -> residual node MLP) carrying an
//!   invariant channel `h [N,H]` and an equivariant channel `v [N,3]`;
//! * branch — 3 FC trunk layers splitting into an energy-per-atom sub-head
//!   (masked segment-sum per graph) and a force sub-head (scalar gate times
//!   the vector channel);
//! * loss — the paper's weighted energy+force MSE with masked MAE metrics.
//!
//! Everything runs on the padded `GraphBatch` flat buffers directly (no
//! Literal marshalling) at one of two precisions (the [`Precision`] knob,
//! carried in [`EgnnDims`]): the default **f64** path computes everything
//! in scalar f64 and is the byte-for-byte-stable gradcheck oracle; the
//! **mixed-f32** path routes the matmul and silu/gate hot spots through
//! the blocked f32-compute / f64-accumulate microkernels of
//! [`crate::model::kernels`] while keeping the loss reduction, scatter
//! aggregation and gradient seeds in f64. On both paths the heavy
//! per-edge / per-node matmuls fan out over scoped worker threads — the
//! same pattern as `data::FeaturizedStore::build` — and row/column
//! chunking never changes the within-row accumulation order, so results
//! are **bit-identical for any thread count** at a fixed precision: the
//! reproducibility and checkpoint-parity guarantees hold on the native
//! path too. Gradients are validated against central finite differences
//! for every parameter leaf (f64) and bounded against the f64 oracle
//! (mixed-f32) in `rust/tests/gradcheck.rs`.

use crate::data::batch::GraphBatch;
use crate::model::kernels::{
    self, colsum_into, dot, dsilu, grad_w_into, grad_x_into, linear_into, map_silu, mul_dsilu,
    Precision,
};
use crate::model::params::ParamSet;
use crate::runtime::manifest::ManifestConfig;

// ---------------------------------------------------------------------------
// dimensions
// ---------------------------------------------------------------------------

/// Static model + batch dimensions of one native execution.
#[derive(Debug, Clone, Copy)]
pub struct EgnnDims {
    /// Padded nodes / edges / graphs per batch.
    pub n: usize,
    pub e: usize,
    pub g: usize,
    /// Species vocabulary, hidden width, EGNN layers, RBF features, head width.
    pub s: usize,
    pub h: usize,
    pub l: usize,
    pub r: usize,
    pub d: usize,
    pub cutoff: f64,
    pub w_energy: f64,
    pub w_force: f64,
    /// Compute precision of the matmul + silu/gate kernels (see
    /// [`crate::model::kernels`]); the loss and the scatter/gather passes
    /// stay f64 at either setting.
    pub precision: Precision,
}

impl EgnnDims {
    /// Dims at the default [`Precision::F64`] (the oracle path).
    pub fn from_config(c: &ManifestConfig) -> EgnnDims {
        Self::from_config_with(c, Precision::F64)
    }

    /// Dims with an explicit compute precision.
    pub fn from_config_with(c: &ManifestConfig, precision: Precision) -> EgnnDims {
        EgnnDims {
            n: c.max_nodes,
            e: c.max_edges,
            g: c.max_graphs,
            s: c.num_species,
            h: c.hidden,
            l: c.num_layers,
            r: c.num_rbf,
            d: c.head_hidden,
            cutoff: c.cutoff,
            w_energy: c.energy_weight,
            w_force: c.force_weight,
            precision,
        }
    }

    /// Edge-MLP input width: [h_src | h_dst | rbf].
    fn kx(&self) -> usize {
        2 * self.h + self.r
    }
}

// ---------------------------------------------------------------------------
// parameters (f64 working copies; the same structs hold gradients)
// ---------------------------------------------------------------------------

/// Cached f32 views of one layer's matmul / gate weights (the serving fast
/// path; see [`EncoderParams::cache_f32`]). Biases stay f64 — the mixed
/// kernels add them at full precision.
struct LayerW32 {
    ew1: Vec<f32>,
    ew2: Vec<f32>,
    wg: Vec<f32>,
    nw1: Vec<f32>,
    nw2: Vec<f32>,
}

/// One EGNN block's parameters (or their gradients).
pub struct LayerParams {
    pub ew1: Vec<f64>, // [(2H+R), H]
    pub eb1: Vec<f64>, // [H]
    pub ew2: Vec<f64>, // [H, H]
    pub eb2: Vec<f64>, // [H]
    pub wg: Vec<f64>,  // [H] (manifest shape [H,1])
    pub bg: f64,
    pub nw1: Vec<f64>, // [2H, H]
    pub nb1: Vec<f64>, // [H]
    pub nw2: Vec<f64>, // [H, H]
    pub nb2: Vec<f64>, // [H]
    /// Cached f32 weight view; `None` until [`EncoderParams::cache_f32`]
    /// runs (gradient instances never populate it).
    w32: Option<LayerW32>,
}

/// Shared-encoder parameters (or their gradients).
pub struct EncoderParams {
    pub embed: Vec<f64>, // [S, H]
    pub layers: Vec<LayerParams>,
}

/// Cached f32 views of one branch's matmul / sub-head weights (see
/// [`BranchParams::cache_f32`]).
struct BranchW32 {
    tw1: Vec<f32>,
    tw2: Vec<f32>,
    tw3: Vec<f32>,
    ew: Vec<f32>,
    fw: Vec<f32>,
}

/// One branch's parameters (or their gradients).
pub struct BranchParams {
    pub tw1: Vec<f64>, // [H, D]
    pub tb1: Vec<f64>, // [D]
    pub tw2: Vec<f64>, // [D, D]
    pub tb2: Vec<f64>, // [D]
    pub tw3: Vec<f64>, // [D, D]
    pub tb3: Vec<f64>, // [D]
    pub ew: Vec<f64>,  // [D] (manifest shape [D,1])
    pub eb: f64,
    pub fw: Vec<f64>,  // [D]
    pub fb: f64,
    /// Cached f32 weight view; `None` until [`BranchParams::cache_f32`].
    w32: Option<BranchW32>,
}

fn leaf_f64(p: &ParamSet, name: &str, numel: usize) -> anyhow::Result<Vec<f64>> {
    let t = p
        .get(name)
        .ok_or_else(|| anyhow::anyhow!("missing parameter leaf '{name}'"))?;
    let v = t.as_f32();
    anyhow::ensure!(
        v.len() == numel,
        "parameter leaf '{name}': {} values, expected {numel}",
        v.len()
    );
    Ok(v.iter().map(|&x| x as f64).collect())
}

fn leaf_scalar(p: &ParamSet, name: &str) -> anyhow::Result<f64> {
    Ok(leaf_f64(p, name, 1)?[0])
}

/// Look a leaf up under `encoder.<name>` first, then bare `<name>` — the
/// encoder-only entry point accepts both spellings, like the PJRT path.
fn enc_name(p: &ParamSet, suffix: &str) -> String {
    let prefixed = format!("encoder.{suffix}");
    if p.get(&prefixed).is_some() {
        prefixed
    } else {
        suffix.to_string()
    }
}

impl EncoderParams {
    /// Extract (upcast) encoder leaves from a parameter set. Accepts full
    /// sets (`encoder.*` names) and encoder-only sets (bare names).
    pub fn from_set(dims: &EgnnDims, p: &ParamSet) -> anyhow::Result<EncoderParams> {
        let (h, r) = (dims.h, dims.r);
        let embed = leaf_f64(p, &enc_name(p, "embed"), dims.s * h)?;
        let mut layers = Vec::with_capacity(dims.l);
        for li in 0..dims.l {
            let name = |part: &str| enc_name(p, &format!("layers.{li}.{part}"));
            layers.push(LayerParams {
                ew1: leaf_f64(p, &name("edge.w1"), (2 * h + r) * h)?,
                eb1: leaf_f64(p, &name("edge.b1"), h)?,
                ew2: leaf_f64(p, &name("edge.w2"), h * h)?,
                eb2: leaf_f64(p, &name("edge.b2"), h)?,
                wg: leaf_f64(p, &name("edge.wg"), h)?,
                bg: leaf_scalar(p, &name("edge.bg"))?,
                nw1: leaf_f64(p, &name("node.w1"), 2 * h * h)?,
                nb1: leaf_f64(p, &name("node.b1"), h)?,
                nw2: leaf_f64(p, &name("node.w2"), h * h)?,
                nb2: leaf_f64(p, &name("node.b2"), h)?,
                w32: None,
            });
        }
        Ok(EncoderParams { embed, layers })
    }

    /// Downcast the matmul / gate weights to f32 once (the serving fast
    /// path; per-call mixed kernels would otherwise re-downcast on every
    /// invocation). The cached view is elementwise identical to what each
    /// uncached call computes — [`kernels::downcast`] is the single shared
    /// definition — so results stay bit-identical either way. A no-op
    /// beyond the first call.
    pub fn cache_f32(&mut self) {
        for lp in &mut self.layers {
            if lp.w32.is_none() {
                lp.w32 = Some(LayerW32 {
                    ew1: kernels::downcast(&lp.ew1),
                    ew2: kernels::downcast(&lp.ew2),
                    wg: kernels::downcast(&lp.wg),
                    nw1: kernels::downcast(&lp.nw1),
                    nw2: kernels::downcast(&lp.nw2),
                });
            }
        }
    }

    pub fn zeros(dims: &EgnnDims) -> EncoderParams {
        let h = dims.h;
        let layers = (0..dims.l)
            .map(|_| LayerParams {
                ew1: vec![0.0; dims.kx() * h],
                eb1: vec![0.0; h],
                ew2: vec![0.0; h * h],
                eb2: vec![0.0; h],
                wg: vec![0.0; h],
                bg: 0.0,
                nw1: vec![0.0; 2 * h * h],
                nb1: vec![0.0; h],
                nw2: vec![0.0; h * h],
                nb2: vec![0.0; h],
                w32: None,
            })
            .collect();
        EncoderParams { embed: vec![0.0; dims.s * h], layers }
    }
}

impl BranchParams {
    pub fn from_set(dims: &EgnnDims, p: &ParamSet) -> anyhow::Result<BranchParams> {
        let (h, d) = (dims.h, dims.d);
        Ok(BranchParams {
            tw1: leaf_f64(p, "branch.trunk.w1", h * d)?,
            tb1: leaf_f64(p, "branch.trunk.b1", d)?,
            tw2: leaf_f64(p, "branch.trunk.w2", d * d)?,
            tb2: leaf_f64(p, "branch.trunk.b2", d)?,
            tw3: leaf_f64(p, "branch.trunk.w3", d * d)?,
            tb3: leaf_f64(p, "branch.trunk.b3", d)?,
            ew: leaf_f64(p, "branch.energy.w", d)?,
            eb: leaf_scalar(p, "branch.energy.b")?,
            fw: leaf_f64(p, "branch.force.w", d)?,
            fb: leaf_scalar(p, "branch.force.b")?,
            w32: None,
        })
    }

    /// Downcast the trunk / sub-head weights to f32 once; see
    /// [`EncoderParams::cache_f32`] for the bit-identity argument.
    pub fn cache_f32(&mut self) {
        if self.w32.is_none() {
            self.w32 = Some(BranchW32 {
                tw1: kernels::downcast(&self.tw1),
                tw2: kernels::downcast(&self.tw2),
                tw3: kernels::downcast(&self.tw3),
                ew: kernels::downcast(&self.ew),
                fw: kernels::downcast(&self.fw),
            });
        }
    }

    pub fn zeros(dims: &EgnnDims) -> BranchParams {
        let d = dims.d;
        BranchParams {
            tw1: vec![0.0; dims.h * d],
            tb1: vec![0.0; d],
            tw2: vec![0.0; d * d],
            tb2: vec![0.0; d],
            tw3: vec![0.0; d * d],
            tb3: vec![0.0; d],
            ew: vec![0.0; d],
            eb: 0.0,
            fw: vec![0.0; d],
            fb: 0.0,
            w32: None,
        }
    }
}

// ---------------------------------------------------------------------------
// batch view (f64 upcast + index sanitation, once per step)
// ---------------------------------------------------------------------------

/// Upcast view of one padded batch.
pub struct Batch64 {
    species: Vec<usize>,
    src: Vec<usize>,
    dst: Vec<usize>,
    node_graph: Vec<usize>,
    dist: Vec<f64>,
    rel_hat: Vec<f64>,
    nmask: Vec<f64>,
    emask: Vec<f64>,
    gmask: Vec<f64>,
    inv_atoms: Vec<f64>,
    y_e: Vec<f64>,
    y_f: Vec<f64>,
}

impl Batch64 {
    /// An empty view; [`Batch64::refill`] before use. Serving workspaces
    /// hold one of these so the twelve upcast buffers are allocated once
    /// and recycled across requests.
    pub fn empty() -> Batch64 {
        Batch64 {
            species: Vec::new(),
            src: Vec::new(),
            dst: Vec::new(),
            node_graph: Vec::new(),
            dist: Vec::new(),
            rel_hat: Vec::new(),
            nmask: Vec::new(),
            emask: Vec::new(),
            gmask: Vec::new(),
            inv_atoms: Vec::new(),
            y_e: Vec::new(),
            y_f: Vec::new(),
        }
    }

    pub fn new(dims: &EgnnDims, b: &GraphBatch) -> anyhow::Result<Batch64> {
        let mut out = Batch64::empty();
        out.refill(dims, b)?;
        Ok(out)
    }

    /// Rebuild the upcast view in place, reusing the existing allocations
    /// (values are identical to a fresh [`Batch64::new`]).
    pub fn refill(&mut self, dims: &EgnnDims, b: &GraphBatch) -> anyhow::Result<()> {
        anyhow::ensure!(
            b.dims.max_nodes == dims.n
                && b.dims.max_edges == dims.e
                && b.dims.max_graphs == dims.g,
            "batch dims {:?} do not match the model config ({}/{}/{})",
            b.dims,
            dims.n,
            dims.e,
            dims.g
        );
        let idx = |v: i32, cap: usize| (v.max(0) as usize).min(cap - 1);
        // jnp indexing clamps out-of-range ids; mirror that so an exotic
        // palette can never read out of bounds.
        self.species.clear();
        self.species.extend(b.species.iter().map(|&z| idx(z, dims.s)));
        self.src.clear();
        self.src.extend(b.edge_src.iter().map(|&i| idx(i, dims.n)));
        self.dst.clear();
        self.dst.extend(b.edge_dst.iter().map(|&i| idx(i, dims.n)));
        self.node_graph.clear();
        self.node_graph.extend(b.node_graph.iter().map(|&i| idx(i, dims.g)));
        self.dist.clear();
        self.dist.extend(b.dist.iter().map(|&x| x as f64));
        self.rel_hat.clear();
        self.rel_hat.extend(b.rel_hat.iter().map(|&x| x as f64));
        self.nmask.clear();
        self.nmask.extend(b.node_mask.iter().map(|&x| x as f64));
        self.emask.clear();
        self.emask.extend(b.edge_mask.iter().map(|&x| x as f64));
        self.gmask.clear();
        self.gmask.extend(b.graph_mask.iter().map(|&x| x as f64));
        self.inv_atoms.clear();
        self.inv_atoms.extend(b.inv_atoms.iter().map(|&x| x as f64));
        self.y_e.clear();
        self.y_e.extend(b.y_energy.iter().map(|&x| x as f64));
        self.y_f.clear();
        self.y_f.extend(b.y_forces.iter().map(|&x| x as f64));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// precision-dispatched kernel wrappers
// ---------------------------------------------------------------------------
//
// The matmul and elementwise kernels themselves (both the f64 oracle and
// the blocked mixed-f32 implementations) live in `crate::model::kernels`;
// everything below selects between them from `EgnnDims::precision`. The
// F64 arms call exactly the kernels (in exactly the order) the
// pre-precision engine used, keeping that path byte-for-byte stable.

/// out[m,n] = x[m,k] @ w[k,n] + b[n], precision-dispatched.
#[allow(clippy::too_many_arguments)]
fn lin(
    p: Precision,
    x: &[f64],
    w: &[f64],
    b: &[f64],
    out: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    match p {
        Precision::F64 => linear_into(x, w, b, out, m, k, n),
        Precision::MixedF32 => kernels::linear_into_mixed(x, w, b, out, m, k, n),
    }
}

/// Linear followed by silu: fills the pre-activation `pre` (cached for the
/// backward pass) and returns the activation. The MixedF32 arm runs the
/// fused kernel — one memory pass over the output block.
#[allow(clippy::too_many_arguments)]
fn lin_silu(
    p: Precision,
    x: &[f64],
    w: &[f64],
    b: &[f64],
    pre: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f64> {
    match p {
        Precision::F64 => {
            linear_into(x, w, b, pre, m, k, n);
            map_silu(pre)
        }
        Precision::MixedF32 => {
            let mut act = vec![0.0; m * n];
            kernels::linear_silu_into_mixed(x, w, b, pre, &mut act, m, k, n);
            act
        }
    }
}

/// gw += x^T @ dy, precision-dispatched.
fn gw_into(p: Precision, x: &[f64], dy: &[f64], gw: &mut [f64], m: usize, k: usize, n: usize) {
    match p {
        Precision::F64 => grad_w_into(x, dy, gw, m, k, n),
        Precision::MixedF32 => kernels::grad_w_into_mixed(x, dy, gw, m, k, n),
    }
}

/// dx += dy @ w^T, precision-dispatched.
fn gx_into(p: Precision, dy: &[f64], w: &[f64], dx: &mut [f64], m: usize, k: usize, n: usize) {
    match p {
        Precision::F64 => grad_x_into(dy, w, dx, m, k, n),
        Precision::MixedF32 => kernels::grad_x_into_mixed(dy, w, dx, m, k, n),
    }
}

#[inline]
fn dot_p(p: Precision, a: &[f64], b: &[f64]) -> f64 {
    match p {
        Precision::F64 => dot(a, b),
        Precision::MixedF32 => kernels::dot_mixed(a, b),
    }
}

#[inline]
fn tanh_p(p: Precision, x: f64) -> f64 {
    match p {
        Precision::F64 => x.tanh(),
        Precision::MixedF32 => kernels::tanh_mixed(x),
    }
}

#[inline]
fn dsilu_p(p: Precision, x: f64) -> f64 {
    match p {
        Precision::F64 => dsilu(x),
        Precision::MixedF32 => kernels::dsilu_mixed(x),
    }
}

fn mul_dsilu_p(p: Precision, dy: &[f64], a: &[f64]) -> Vec<f64> {
    match p {
        Precision::F64 => mul_dsilu(dy, a),
        Precision::MixedF32 => kernels::mul_dsilu_mixed(dy, a),
    }
}

// Cached-weight-view twins of `lin` / `lin_silu` / `dot_p` for the
// eval-only forward: the F64 arm ignores the cache (it computes in f64
// directly), the MixedF32 arm uses the pre-downcast view when present and
// falls back to the per-call downcast otherwise. All three are
// bit-identical to their uncached twins (`kernels::downcast` is the one
// shared definition of the f64 -> f32 cast).

/// `out = x @ w + b` against an optional cached f32 weight view.
#[allow(clippy::too_many_arguments)]
fn lin_w(
    p: Precision,
    x: &[f64],
    w: &[f64],
    w32: Option<&[f32]>,
    b: &[f64],
    out: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    match p {
        Precision::F64 => linear_into(x, w, b, out, m, k, n),
        Precision::MixedF32 => match w32 {
            Some(w32) => kernels::linear_into_mixed_w32(x, w32, b, out, m, k, n),
            None => kernels::linear_into_mixed(x, w, b, out, m, k, n),
        },
    }
}

/// Fused linear + silu into caller-owned `pre`/`act` buffers, against an
/// optional cached f32 weight view. The F64 arm writes `silu(pre)`
/// elementwise into `act` — the same values [`lin_silu`] returns.
#[allow(clippy::too_many_arguments)]
fn lin_silu_w(
    p: Precision,
    x: &[f64],
    w: &[f64],
    w32: Option<&[f32]>,
    b: &[f64],
    pre: &mut [f64],
    act: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    match p {
        Precision::F64 => {
            linear_into(x, w, b, pre, m, k, n);
            for (o, &v) in act.iter_mut().zip(pre.iter()) {
                *o = kernels::silu(v);
            }
        }
        Precision::MixedF32 => match w32 {
            Some(w32) => kernels::linear_silu_into_mixed_w32(x, w32, b, pre, act, m, k, n),
            None => kernels::linear_silu_into_mixed(x, w, b, pre, act, m, k, n),
        },
    }
}

/// Dot product against an optional cached f32 view of `w`.
#[inline]
fn dot_w(p: Precision, a: &[f64], w: &[f64], w32: Option<&[f32]>) -> f64 {
    match p {
        Precision::F64 => dot(a, w),
        Precision::MixedF32 => match w32 {
            Some(w32) => kernels::dot_mixed_w32(a, w32),
            None => kernels::dot_mixed(a, w),
        },
    }
}

// ---------------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------------

/// Per-layer activations kept for the backward pass.
struct LayerCache {
    h_in: Vec<f64>, // [N,H] layer input
    ae1: Vec<f64>,  // [E,H] edge pre-activation 1
    u: Vec<f64>,    // [E,H] silu(ae1)
    ae2: Vec<f64>,  // [E,H] edge pre-activation 2
    m: Vec<f64>,    // [E,H] masked messages
    gate: Vec<f64>, // [E] tanh gate
    hagg: Vec<f64>, // [N,H] raw message scatter-sum (pre inv_deg)
    an1: Vec<f64>,  // [N,H] node pre-activation 1
    s1: Vec<f64>,   // [N,H] silu(an1)
}

/// Encoder output + cached intermediates.
pub struct EncoderState {
    rbf: Vec<f64>,     // [E,R]
    inv_deg: Vec<f64>, // [N]
    layers: Vec<LayerCache>,
    /// Final invariant node features [N,H].
    pub h: Vec<f64>,
    /// Final equivariant channel [N,3].
    pub v: Vec<f64>,
}

/// Branch output + cached intermediates.
pub struct BranchState {
    at1: Vec<f64>,
    z1: Vec<f64>,
    at2: Vec<f64>,
    z2: Vec<f64>,
    at3: Vec<f64>,
    z3: Vec<f64>,
    fr: Vec<f64>, // [N] raw force gate
    /// Predicted energy per atom [G].
    pub e_pa: Vec<f64>,
    /// Predicted forces [N,3].
    pub forces: Vec<f64>,
}

/// Scalar outputs of one loss evaluation.
#[derive(Debug, Clone, Copy)]
pub struct Metrics {
    pub loss: f64,
    pub mae_e: f64,
    pub mae_f: f64,
}

/// Build the [h_src | h_dst | rbf] edge-MLP input (same rows for padded
/// edges as the jax reference: contributions are masked downstream).
fn build_edge_input(x: &mut [f64], hbuf: &[f64], rbf: &[f64], b: &Batch64, dims: &EgnnDims) {
    let (h, r) = (dims.h, dims.r);
    let kx = dims.kx();
    for ei in 0..dims.e {
        let (si, di) = (b.src[ei], b.dst[ei]);
        let row = &mut x[ei * kx..(ei + 1) * kx];
        row[..h].copy_from_slice(&hbuf[si * h..(si + 1) * h]);
        row[h..2 * h].copy_from_slice(&hbuf[di * h..(di + 1) * h]);
        row[2 * h..].copy_from_slice(&rbf[ei * r..(ei + 1) * r]);
    }
}

/// Masked Gaussian RBF (cosine cutoff envelope) + degree normalization
/// `1 / (1 + in-degree)` — the shared encoder prologue.
fn rbf_and_inv_deg(dims: &EgnnDims, b: &Batch64) -> (Vec<f64>, Vec<f64>) {
    let (n, e, r) = (dims.n, dims.e, dims.r);
    let mut rbf = vec![0.0; e * r];
    let gamma = (r as f64 / dims.cutoff).powi(2);
    for ei in 0..e {
        if b.emask[ei] == 0.0 {
            continue;
        }
        let dist = b.dist[ei];
        let env =
            0.5 * ((std::f64::consts::PI * (dist / dims.cutoff).clamp(0.0, 1.0)).cos() + 1.0);
        for ri in 0..r {
            let c = if r > 1 { dims.cutoff * ri as f64 / (r - 1) as f64 } else { 0.0 };
            let dd = dist - c;
            rbf[ei * r + ri] = (-gamma * dd * dd).exp() * env * b.emask[ei];
        }
    }
    let mut deg = vec![0.0; n];
    for ei in 0..e {
        deg[b.dst[ei]] += b.emask[ei];
    }
    let inv_deg = deg.iter().map(|&x| 1.0 / (1.0 + x)).collect();
    (rbf, inv_deg)
}

/// h0 = embed[species] * node_mask.
fn embed_h0(dims: &EgnnDims, enc: &EncoderParams, b: &Batch64) -> Vec<f64> {
    let (n, h) = (dims.n, dims.h);
    let mut hbuf = vec![0.0; n * h];
    for nd in 0..n {
        let nm = b.nmask[nd];
        if nm == 0.0 {
            continue;
        }
        let sp = b.species[nd];
        for j in 0..h {
            hbuf[nd * h + j] = enc.embed[sp * h + j] * nm;
        }
    }
    hbuf
}

/// One message-passing block from its input features `h_in`: writes the
/// layer output into `h_out`, accumulates the equivariant update into `v`,
/// and returns the full activation cache. The single code path behind both
/// encoder forwards — [`encoder_forward`] retains every returned cache,
/// [`encoder_forward_checkpoint`] keeps only `h_in` and recomputes the rest
/// during [`backward_checkpoint`] — so the two are bit-identical by
/// construction.
fn layer_forward(
    dims: &EgnnDims,
    lp: &LayerParams,
    b: &Batch64,
    rbf: &[f64],
    inv_deg: &[f64],
    h_in: Vec<f64>,
    h_out: &mut [f64],
    v: &mut [f64],
) -> LayerCache {
    let (n, e, h) = (dims.n, dims.e, dims.h);
    let p = dims.precision;
    let kx = dims.kx();
    let mut x = vec![0.0; e * kx];
    build_edge_input(&mut x, &h_in, rbf, b, dims);

    let mut ae1 = vec![0.0; e * h];
    let u = lin_silu(p, &x, &lp.ew1, &lp.eb1, &mut ae1, e, kx, h);
    let mut ae2 = vec![0.0; e * h];
    let mut m = lin_silu(p, &u, &lp.ew2, &lp.eb2, &mut ae2, e, h, h);
    for ei in 0..e {
        if b.emask[ei] == 0.0 {
            m[ei * h..(ei + 1) * h].fill(0.0);
        }
    }
    let mut gate = vec![0.0; e];
    for ei in 0..e {
        gate[ei] = tanh_p(p, dot_p(p, &m[ei * h..(ei + 1) * h], &lp.wg) + lp.bg);
    }

    // Scatter aggregation (serial, edge order: deterministic).
    let mut hagg = vec![0.0; n * h];
    for ei in 0..e {
        if b.emask[ei] == 0.0 {
            continue;
        }
        let nd = b.dst[ei];
        for j in 0..h {
            hagg[nd * h + j] += m[ei * h + j];
        }
    }
    for ei in 0..e {
        let em = b.emask[ei];
        if em == 0.0 {
            continue;
        }
        let nd = b.dst[ei];
        let sc = gate[ei] * em * inv_deg[nd] * b.nmask[nd];
        for k in 0..3 {
            v[nd * 3 + k] += b.rel_hat[ei * 3 + k] * sc;
        }
    }

    // Residual node update on [h | hagg * inv_deg].
    let mut nin = vec![0.0; n * 2 * h];
    for nd in 0..n {
        nin[nd * 2 * h..nd * 2 * h + h].copy_from_slice(&h_in[nd * h..(nd + 1) * h]);
        let id = inv_deg[nd];
        for j in 0..h {
            nin[nd * 2 * h + h + j] = hagg[nd * h + j] * id;
        }
    }
    let mut an1 = vec![0.0; n * h];
    let s1 = lin_silu(p, &nin, &lp.nw1, &lp.nb1, &mut an1, n, 2 * h, h);
    let mut upd = vec![0.0; n * h];
    lin(p, &s1, &lp.nw2, &lp.nb2, &mut upd, n, h, h);
    for nd in 0..n {
        let nm = b.nmask[nd];
        for j in 0..h {
            h_out[nd * h + j] = (h_in[nd * h + j] + upd[nd * h + j]) * nm;
        }
    }
    LayerCache { h_in, ae1, u, ae2, m, gate, hagg, an1, s1 }
}

/// Shared-encoder forward pass with cached intermediates.
pub fn encoder_forward(dims: &EgnnDims, enc: &EncoderParams, b: &Batch64) -> EncoderState {
    let (rbf, inv_deg) = rbf_and_inv_deg(dims, b);
    let mut hbuf = embed_h0(dims, enc, b);
    let mut v = vec![0.0; dims.n * 3];
    let mut layers = Vec::with_capacity(dims.l);
    for lp in &enc.layers {
        let h_in = hbuf.clone();
        layers.push(layer_forward(dims, lp, b, &rbf, &inv_deg, h_in, &mut hbuf, &mut v));
    }
    EncoderState { rbf, inv_deg, layers, h: hbuf, v }
}

/// Gradient-checkpointed encoder forward state: only each block's INPUT
/// features survive the forward pass. The eight other per-layer activation
/// buffers (`[E,H]` x 5 + `[N,H]` x 3 in [`LayerCache`]) are recomputed one
/// layer at a time inside [`backward_checkpoint`] — for the edge-heavy
/// graphs of the graph-parallel path that cuts retained forward state by
/// roughly the edge/node ratio, at the cost of one extra block forward per
/// layer in the backward sweep.
pub struct CheckpointedEncoder {
    rbf: Vec<f64>,
    inv_deg: Vec<f64>,
    h_ins: Vec<Vec<f64>>,
    /// Final invariant node features [N,H].
    pub h: Vec<f64>,
    /// Final equivariant channel [N,3].
    pub v: Vec<f64>,
}

/// As [`encoder_forward`] — same helper, same operation order, bit-identical
/// `h` and `v` — but retaining only the per-layer inputs (see
/// [`CheckpointedEncoder`]).
pub fn encoder_forward_checkpoint(
    dims: &EgnnDims,
    enc: &EncoderParams,
    b: &Batch64,
) -> CheckpointedEncoder {
    let (rbf, inv_deg) = rbf_and_inv_deg(dims, b);
    let mut hbuf = embed_h0(dims, enc, b);
    let mut v = vec![0.0; dims.n * 3];
    let mut h_ins = Vec::with_capacity(dims.l);
    for lp in &enc.layers {
        let h_in = hbuf.clone();
        let lc = layer_forward(dims, lp, b, &rbf, &inv_deg, h_in, &mut hbuf, &mut v);
        h_ins.push(lc.h_in);
    }
    CheckpointedEncoder { rbf, inv_deg, h_ins, h: hbuf, v }
}

/// Branch forward pass (trunk MLP -> energy-per-atom + force sub-heads).
pub fn branch_forward(
    dims: &EgnnDims,
    br: &BranchParams,
    es: &EncoderState,
    b: &Batch64,
) -> BranchState {
    branch_forward_h(dims, br, &es.h, &es.v, b)
}

/// [`branch_forward`] from raw encoder outputs — the entry point for the
/// checkpointed path, whose [`CheckpointedEncoder`] is not an
/// [`EncoderState`]. Identical computation.
pub fn branch_forward_h(
    dims: &EgnnDims,
    br: &BranchParams,
    enc_h: &[f64],
    enc_v: &[f64],
    b: &Batch64,
) -> BranchState {
    let (n, g, h, d) = (dims.n, dims.g, dims.h, dims.d);
    let p = dims.precision;
    let mut at1 = vec![0.0; n * d];
    let z1 = lin_silu(p, enc_h, &br.tw1, &br.tb1, &mut at1, n, h, d);
    let mut at2 = vec![0.0; n * d];
    let z2 = lin_silu(p, &z1, &br.tw2, &br.tb2, &mut at2, n, d, d);
    let mut at3 = vec![0.0; n * d];
    let z3 = lin_silu(p, &z2, &br.tw3, &br.tb3, &mut at3, n, d, d);

    let mut er = vec![0.0; n];
    let mut fr = vec![0.0; n];
    for nd in 0..n {
        let zrow = &z3[nd * d..(nd + 1) * d];
        er[nd] = dot_p(p, zrow, &br.ew) + br.eb;
        fr[nd] = dot_p(p, zrow, &br.fw) + br.fb;
    }

    // Masked per-graph segment sum, normalized to energy per atom.
    let mut e_pa = vec![0.0; g];
    for nd in 0..n {
        e_pa[b.node_graph[nd]] += er[nd] * b.nmask[nd];
    }
    for gq in 0..g {
        e_pa[gq] *= b.inv_atoms[gq];
    }

    // Force = scalar gate x equivariant channel, masked.
    let mut forces = vec![0.0; n * 3];
    for nd in 0..n {
        let sc = fr[nd] * b.nmask[nd];
        if sc != 0.0 {
            for k in 0..3 {
                forces[nd * 3 + k] = sc * enc_v[nd * 3 + k];
            }
        }
    }
    BranchState { at1, z1, at2, z2, at3, z3, fr, e_pa, forces }
}

// ---------------------------------------------------------------------------
// eval-only forward (the serving path)
// ---------------------------------------------------------------------------

/// Recycled activation workspace for the eval-only forward: every buffer
/// the training forward would allocate (and the `LayerCache`/`BranchState`
/// intermediates it would *retain* for the backward pass, nine `[E,H]` or
/// `[N,H]` buffers per layer) collapses into one fixed set, allocated once
/// per worker and reused across requests — roughly halving peak serving
/// memory and eliminating per-call allocation entirely.
///
/// [`EvalWorkspace::run`] replays the exact operation order of
/// [`encoder_forward`] + [`branch_forward`] (same kernels, same masking,
/// same serial scatter in edge order), so its outputs are bit-identical to
/// the training-path forward at either [`Precision`]; when the parameter
/// structs carry cached f32 views (`cache_f32`), the mixed path
/// additionally skips every per-call weight downcast, again without
/// changing a single bit.
pub struct EvalWorkspace {
    b64: Batch64,
    rbf: Vec<f64>,     // [E,R]
    deg: Vec<f64>,     // [N]
    inv_deg: Vec<f64>, // [N]
    hbuf: Vec<f64>,    // [N,H]
    h_in: Vec<f64>,    // [N,H]
    v: Vec<f64>,       // [N,3]
    x: Vec<f64>,       // [E,2H+R]
    epre: Vec<f64>,    // [E,H] pre-activation scratch (discarded)
    u: Vec<f64>,       // [E,H]
    m: Vec<f64>,       // [E,H]
    gate: Vec<f64>,    // [E]
    hagg: Vec<f64>,    // [N,H]
    nin: Vec<f64>,     // [N,2H]
    npre: Vec<f64>,    // [N,H] pre-activation scratch (discarded)
    s1: Vec<f64>,      // [N,H]
    upd: Vec<f64>,     // [N,H]
    bpre: Vec<f64>,    // [N,D] pre-activation scratch (discarded)
    za: Vec<f64>,      // [N,D] trunk ping
    zb: Vec<f64>,      // [N,D] trunk pong
    er: Vec<f64>,      // [N]
    fr: Vec<f64>,      // [N]
    e_pa: Vec<f64>,    // [G]
    forces: Vec<f64>,  // [N,3]
    out_e: Vec<f32>,   // [G] round-tripped output
    out_f: Vec<f32>,   // [N,3] round-tripped output
}

impl EvalWorkspace {
    pub fn new(dims: &EgnnDims) -> EvalWorkspace {
        let (n, e, g, h, r, d) = (dims.n, dims.e, dims.g, dims.h, dims.r, dims.d);
        EvalWorkspace {
            b64: Batch64::empty(),
            rbf: vec![0.0; e * r],
            deg: vec![0.0; n],
            inv_deg: vec![0.0; n],
            hbuf: vec![0.0; n * h],
            h_in: vec![0.0; n * h],
            v: vec![0.0; n * 3],
            x: vec![0.0; e * dims.kx()],
            epre: vec![0.0; e * h],
            u: vec![0.0; e * h],
            m: vec![0.0; e * h],
            gate: vec![0.0; e],
            hagg: vec![0.0; n * h],
            nin: vec![0.0; n * 2 * h],
            npre: vec![0.0; n * h],
            s1: vec![0.0; n * h],
            upd: vec![0.0; n * h],
            bpre: vec![0.0; n * d],
            za: vec![0.0; n * d],
            zb: vec![0.0; n * d],
            er: vec![0.0; n],
            fr: vec![0.0; n],
            e_pa: vec![0.0; g],
            forces: vec![0.0; n * 3],
            out_e: vec![0.0; g],
            out_f: vec![0.0; n * 3],
        }
    }

    /// One full eval forward over `batch`; outputs land in
    /// [`EvalWorkspace::energy_per_atom`] / [`EvalWorkspace::forces`],
    /// already round-tripped through f32 exactly like the backend's tensor
    /// outputs, so downstream f64 reads match the `Engine::forward` path
    /// bit-for-bit.
    pub fn run(
        &mut self,
        dims: &EgnnDims,
        enc: &EncoderParams,
        br: &BranchParams,
        batch: &GraphBatch,
    ) -> anyhow::Result<()> {
        self.b64.refill(dims, batch)?;
        let EvalWorkspace {
            b64,
            rbf,
            deg,
            inv_deg,
            hbuf,
            h_in,
            v,
            x,
            epre,
            u,
            m,
            gate,
            hagg,
            nin,
            npre,
            s1,
            upd,
            bpre,
            za,
            zb,
            er,
            fr,
            e_pa,
            forces,
            out_e,
            out_f,
        } = self;
        let b: &Batch64 = b64;
        let (n, e, g, h, r, d) = (dims.n, dims.e, dims.g, dims.h, dims.r, dims.d);
        let p = dims.precision;
        let kx = dims.kx();

        // Gaussian RBF under the cosine cutoff envelope, masked.
        rbf.fill(0.0);
        let gamma = (r as f64 / dims.cutoff).powi(2);
        for ei in 0..e {
            if b.emask[ei] == 0.0 {
                continue;
            }
            let dist = b.dist[ei];
            let env =
                0.5 * ((std::f64::consts::PI * (dist / dims.cutoff).clamp(0.0, 1.0)).cos() + 1.0);
            for ri in 0..r {
                let c = if r > 1 { dims.cutoff * ri as f64 / (r - 1) as f64 } else { 0.0 };
                let dd = dist - c;
                rbf[ei * r + ri] = (-gamma * dd * dd).exp() * env * b.emask[ei];
            }
        }

        // Degree normalization (1 / (1 + in-degree)).
        deg.fill(0.0);
        for ei in 0..e {
            deg[b.dst[ei]] += b.emask[ei];
        }
        for (o, &dg) in inv_deg.iter_mut().zip(deg.iter()) {
            *o = 1.0 / (1.0 + dg);
        }

        // h0 = embed[species] * node_mask; v starts at zero.
        hbuf.fill(0.0);
        for nd in 0..n {
            let nm = b.nmask[nd];
            if nm == 0.0 {
                continue;
            }
            let sp = b.species[nd];
            for j in 0..h {
                hbuf[nd * h + j] = enc.embed[sp * h + j] * nm;
            }
        }
        v.fill(0.0);

        for lp in &enc.layers {
            h_in.copy_from_slice(hbuf);
            build_edge_input(x, h_in, rbf, b, dims);
            let c = lp.w32.as_ref();

            lin_silu_w(p, x, &lp.ew1, c.map(|c| c.ew1.as_slice()), &lp.eb1, epre, u, e, kx, h);
            lin_silu_w(p, u, &lp.ew2, c.map(|c| c.ew2.as_slice()), &lp.eb2, epre, m, e, h, h);
            for ei in 0..e {
                if b.emask[ei] == 0.0 {
                    m[ei * h..(ei + 1) * h].fill(0.0);
                }
            }
            for ei in 0..e {
                let mrow = &m[ei * h..(ei + 1) * h];
                gate[ei] =
                    tanh_p(p, dot_w(p, mrow, &lp.wg, c.map(|c| c.wg.as_slice())) + lp.bg);
            }

            // Scatter aggregation (serial, edge order: deterministic).
            hagg.fill(0.0);
            for ei in 0..e {
                if b.emask[ei] == 0.0 {
                    continue;
                }
                let nd = b.dst[ei];
                for j in 0..h {
                    hagg[nd * h + j] += m[ei * h + j];
                }
            }
            for ei in 0..e {
                let em = b.emask[ei];
                if em == 0.0 {
                    continue;
                }
                let nd = b.dst[ei];
                let sc = gate[ei] * em * inv_deg[nd] * b.nmask[nd];
                for k in 0..3 {
                    v[nd * 3 + k] += b.rel_hat[ei * 3 + k] * sc;
                }
            }

            // Residual node update on [h | hagg * inv_deg].
            for nd in 0..n {
                nin[nd * 2 * h..nd * 2 * h + h].copy_from_slice(&h_in[nd * h..(nd + 1) * h]);
                let id = inv_deg[nd];
                for j in 0..h {
                    nin[nd * 2 * h + h + j] = hagg[nd * h + j] * id;
                }
            }
            lin_silu_w(p, nin, &lp.nw1, c.map(|c| c.nw1.as_slice()), &lp.nb1, npre, s1, n, 2 * h, h);
            lin_w(p, s1, &lp.nw2, c.map(|c| c.nw2.as_slice()), &lp.nb2, upd, n, h, h);
            for nd in 0..n {
                let nm = b.nmask[nd];
                for j in 0..h {
                    hbuf[nd * h + j] = (h_in[nd * h + j] + upd[nd * h + j]) * nm;
                }
            }
        }

        // Branch: trunk MLP -> energy-per-atom + force sub-heads.
        let c = br.w32.as_ref();
        lin_silu_w(p, hbuf, &br.tw1, c.map(|c| c.tw1.as_slice()), &br.tb1, bpre, za, n, h, d);
        lin_silu_w(p, za, &br.tw2, c.map(|c| c.tw2.as_slice()), &br.tb2, bpre, zb, n, d, d);
        lin_silu_w(p, zb, &br.tw3, c.map(|c| c.tw3.as_slice()), &br.tb3, bpre, za, n, d, d);

        for nd in 0..n {
            let zrow = &za[nd * d..(nd + 1) * d];
            er[nd] = dot_w(p, zrow, &br.ew, c.map(|c| c.ew.as_slice())) + br.eb;
            fr[nd] = dot_w(p, zrow, &br.fw, c.map(|c| c.fw.as_slice())) + br.fb;
        }

        // Masked per-graph segment sum, normalized to energy per atom.
        e_pa.fill(0.0);
        for nd in 0..n {
            e_pa[b.node_graph[nd]] += er[nd] * b.nmask[nd];
        }
        for gq in 0..g {
            e_pa[gq] *= b.inv_atoms[gq];
        }

        // Force = scalar gate x equivariant channel, masked.
        forces.fill(0.0);
        for nd in 0..n {
            let sc = fr[nd] * b.nmask[nd];
            if sc != 0.0 {
                for k in 0..3 {
                    forces[nd * 3 + k] = sc * v[nd * 3 + k];
                }
            }
        }

        // The same f64 -> f32 round trip `NativeBackend::forward` applies
        // when materializing its output tensors.
        for (o, &e_) in out_e.iter_mut().zip(e_pa.iter()) {
            *o = e_ as f32;
        }
        for (o, &f_) in out_f.iter_mut().zip(forces.iter()) {
            *o = f_ as f32;
        }
        Ok(())
    }

    /// Predicted energy per atom `[G]` of the last [`EvalWorkspace::run`].
    pub fn energy_per_atom(&self) -> &[f32] {
        &self.out_e
    }

    /// Predicted forces `[N,3]` of the last [`EvalWorkspace::run`].
    pub fn forces(&self) -> &[f32] {
        &self.out_f
    }
}

/// The paper's weighted energy+force loss with masked MAE metrics.
pub fn loss_metrics(dims: &EgnnDims, b: &Batch64, bs: &BranchState) -> Metrics {
    let n_g = b.gmask.iter().sum::<f64>().max(1.0);
    let n_n = b.nmask.iter().sum::<f64>().max(1.0);
    let mut se = 0.0;
    let mut ae = 0.0;
    for gq in 0..dims.g {
        let de = (bs.e_pa[gq] - b.y_e[gq]) * b.gmask[gq];
        se += de * de;
        ae += de.abs();
    }
    let mut sf = 0.0;
    let mut af = 0.0;
    for nd in 0..dims.n {
        let nm = b.nmask[nd];
        if nm == 0.0 {
            continue;
        }
        for k in 0..3 {
            let df = (bs.forces[nd * 3 + k] - b.y_f[nd * 3 + k]) * nm;
            sf += df * df;
            af += df.abs();
        }
    }
    let mse_e = se / n_g;
    let mse_f = sf / (3.0 * n_n);
    Metrics {
        loss: dims.w_energy * mse_e + dims.w_force * mse_f,
        mae_e: ae / n_g,
        mae_f: af / (3.0 * n_n),
    }
}

// ---------------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------------

/// A completion-ordered block of the analytic backward pass. The backward
/// finishes gradients in a fixed order — all `branch.*` leaves first, then
/// each `encoder.layers.{li}.*` block in REVERSE layer order, and
/// `encoder.embed` last — which is what lets `comm::overlap` start reducing
/// early buckets while later blocks are still being computed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradBlock {
    /// All `branch.*` leaves (trunk + energy/force heads); first to finish.
    Branch,
    /// One message-passing layer's `encoder.layers.{li}.*` leaves. Layer
    /// `L-1` finishes first, layer `0` last.
    Layer(usize),
    /// `encoder.embed` — the final block.
    Embed,
}

impl GradBlock {
    /// Position in backward completion order: `Branch` → 0,
    /// `Layer(li)` → `L - li`, `Embed` → `L + 1`.
    pub fn ordinal(&self, num_layers: usize) -> usize {
        match *self {
            GradBlock::Branch => 0,
            GradBlock::Layer(li) => num_layers - li,
            GradBlock::Embed => num_layers + 1,
        }
    }
}

/// Analytic gradients of the loss wrt every encoder + branch parameter.
/// Validated entry-by-entry against central finite differences in
/// `rust/tests/gradcheck.rs`.
#[allow(clippy::too_many_arguments)]
pub fn backward(
    dims: &EgnnDims,
    enc: &EncoderParams,
    br: &BranchParams,
    es: &EncoderState,
    bs: &BranchState,
    b: &Batch64,
) -> (EncoderParams, BranchParams) {
    backward_observed(dims, enc, br, es, bs, b, &mut |_, _, _| Ok(()))
        .expect("infallible observer: backward itself never errors")
}

/// As [`backward`], signaling each [`GradBlock`]'s completion through
/// `on_block` the moment its gradients are final (the grad containers are
/// passed so the observer can read the finished block; later blocks are
/// still zero at that point). The computation — every operation, in the
/// same order — is exactly [`backward`]'s, so observed and unobserved runs
/// produce bit-identical gradients; the only errors are the observer's own.
#[allow(clippy::too_many_arguments)]
pub fn backward_observed(
    dims: &EgnnDims,
    enc: &EncoderParams,
    br: &BranchParams,
    es: &EncoderState,
    bs: &BranchState,
    b: &Batch64,
    on_block: &mut dyn FnMut(
        GradBlock,
        &EncoderParams,
        &BranchParams,
    ) -> anyhow::Result<()>,
) -> anyhow::Result<(EncoderParams, BranchParams)> {
    let mut gb = BranchParams::zeros(dims);
    let (mut d_h, d_v) = branch_backward(dims, br, &es.h, &es.v, bs, b, &mut gb);

    // --- encoder backward (reverse layer order) ---
    // v accumulates additively across layers, so its cotangent is the same
    // `d_v` at every layer; each layer only extracts its own vagg term.
    let mut ge = EncoderParams::zeros(dims);
    on_block(GradBlock::Branch, &ge, &gb)?;
    for (li, lc) in es.layers.iter().enumerate().rev() {
        d_h = layer_backward(
            dims,
            &enc.layers[li],
            lc,
            b,
            &es.rbf,
            &es.inv_deg,
            &d_h,
            &d_v,
            &mut ge.layers[li],
        );
        on_block(GradBlock::Layer(li), &ge, &gb)?;
    }
    embed_backward(dims, b, &d_h, &mut ge);
    on_block(GradBlock::Embed, &ge, &gb)?;
    Ok((ge, gb))
}

/// As [`backward`], but from a gradient-checkpointed forward
/// ([`encoder_forward_checkpoint`]): each layer's activation cache is
/// recomputed from its saved input immediately before that layer's
/// backward, in reverse layer order, so at most ONE [`LayerCache`] is live
/// at a time. Both sweeps go through the shared
/// [`layer_forward`]/[`layer_backward`] helpers — identical operations in
/// identical order — so the gradients are bit-identical to [`backward`]'s
/// at either precision (pinned by `checkpointed_backward_is_bit_identical`
/// below).
pub fn backward_checkpoint(
    dims: &EgnnDims,
    enc: &EncoderParams,
    br: &BranchParams,
    ck: &CheckpointedEncoder,
    bs: &BranchState,
    b: &Batch64,
) -> (EncoderParams, BranchParams) {
    let mut gb = BranchParams::zeros(dims);
    let (mut d_h, d_v) = branch_backward(dims, br, &ck.h, &ck.v, bs, b, &mut gb);
    let mut ge = EncoderParams::zeros(dims);
    let mut scratch_h = vec![0.0; dims.n * dims.h];
    // The recompute's equivariant updates are discarded (the final `v` is
    // already in `ck.v`; the backward only needs the layer cache).
    let mut scratch_v = vec![0.0; dims.n * 3];
    for li in (0..dims.l).rev() {
        let lp = &enc.layers[li];
        let lc = layer_forward(
            dims,
            lp,
            b,
            &ck.rbf,
            &ck.inv_deg,
            ck.h_ins[li].clone(),
            &mut scratch_h,
            &mut scratch_v,
        );
        d_h = layer_backward(
            dims,
            lp,
            &lc,
            b,
            &ck.rbf,
            &ck.inv_deg,
            &d_h,
            &d_v,
            &mut ge.layers[li],
        );
    }
    embed_backward(dims, b, &d_h, &mut ge);
    (ge, gb)
}

/// Loss seeds + branch backward: accumulates every `branch.*` gradient into
/// `gb` and returns the cotangents flowing into the encoder
/// (`d_h [N,H]`, `d_v [N,3]`).
fn branch_backward(
    dims: &EgnnDims,
    br: &BranchParams,
    enc_h: &[f64],
    enc_v: &[f64],
    bs: &BranchState,
    b: &Batch64,
    gb: &mut BranchParams,
) -> (Vec<f64>, Vec<f64>) {
    let (n, g, h, d) = (dims.n, dims.g, dims.h, dims.d);
    let p = dims.precision;

    // Loss seeds (always f64: full-precision accumulation of the loss and
    // its cotangents, per the mixed-precision recipe).
    let n_g = b.gmask.iter().sum::<f64>().max(1.0);
    let n_n = b.nmask.iter().sum::<f64>().max(1.0);
    let mut d_e_pa = vec![0.0; g];
    for gq in 0..g {
        let de = (bs.e_pa[gq] - b.y_e[gq]) * b.gmask[gq];
        d_e_pa[gq] = dims.w_energy * 2.0 * de * b.gmask[gq] / n_g;
    }
    let denom_f = 3.0 * n_n;
    let mut d_forces = vec![0.0; n * 3];
    for nd in 0..n {
        let nm = b.nmask[nd];
        if nm == 0.0 {
            continue;
        }
        for k in 0..3 {
            let df = (bs.forces[nd * 3 + k] - b.y_f[nd * 3 + k]) * nm;
            d_forces[nd * 3 + k] = dims.w_force * 2.0 * df * nm / denom_f;
        }
    }

    let mut d_er = vec![0.0; n];
    let mut d_fr = vec![0.0; n];
    let mut d_v = vec![0.0; n * 3];
    for nd in 0..n {
        let nm = b.nmask[nd];
        let gq = b.node_graph[nd];
        d_er[nd] = d_e_pa[gq] * b.inv_atoms[gq] * nm;
        let mut s = 0.0;
        for k in 0..3 {
            s += d_forces[nd * 3 + k] * enc_v[nd * 3 + k];
            d_v[nd * 3 + k] = d_forces[nd * 3 + k] * bs.fr[nd] * nm;
        }
        d_fr[nd] = s * nm;
    }
    let mut d_z3 = vec![0.0; n * d];
    for nd in 0..n {
        let (a, c) = (d_er[nd], d_fr[nd]);
        gb.eb += a;
        gb.fb += c;
        if a == 0.0 && c == 0.0 {
            continue;
        }
        let zrow = &bs.z3[nd * d..(nd + 1) * d];
        let drow = &mut d_z3[nd * d..(nd + 1) * d];
        for j in 0..d {
            drow[j] = a * br.ew[j] + c * br.fw[j];
            gb.ew[j] += zrow[j] * a;
            gb.fw[j] += zrow[j] * c;
        }
    }
    let d_at3 = mul_dsilu_p(p, &d_z3, &bs.at3);
    gw_into(p, &bs.z2, &d_at3, &mut gb.tw3, n, d, d);
    colsum_into(&d_at3, &mut gb.tb3, n, d);
    let mut d_z2 = vec![0.0; n * d];
    gx_into(p, &d_at3, &br.tw3, &mut d_z2, n, d, d);
    let d_at2 = mul_dsilu_p(p, &d_z2, &bs.at2);
    gw_into(p, &bs.z1, &d_at2, &mut gb.tw2, n, d, d);
    colsum_into(&d_at2, &mut gb.tb2, n, d);
    let mut d_z1 = vec![0.0; n * d];
    gx_into(p, &d_at2, &br.tw2, &mut d_z1, n, d, d);
    let d_at1 = mul_dsilu_p(p, &d_z1, &bs.at1);
    gw_into(p, enc_h, &d_at1, &mut gb.tw1, n, h, d);
    colsum_into(&d_at1, &mut gb.tb1, n, d);
    let mut d_h = vec![0.0; n * h];
    gx_into(p, &d_at1, &br.tw1, &mut d_h, n, h, d);
    (d_h, d_v)
}

/// One message-passing block's backward from its activation cache:
/// accumulates the layer's gradients into `gl` and returns the cotangent
/// of the layer INPUT (`d_h_in [N,H]`). Shared by the cached and the
/// checkpointed sweeps.
#[allow(clippy::too_many_arguments)]
fn layer_backward(
    dims: &EgnnDims,
    lp: &LayerParams,
    lc: &LayerCache,
    b: &Batch64,
    rbf: &[f64],
    inv_deg: &[f64],
    d_h: &[f64],
    d_v: &[f64],
    gl: &mut LayerParams,
) -> Vec<f64> {
    let (n, e, h) = (dims.n, dims.e, dims.h);
    let p = dims.precision;
    let kx = dims.kx();

    // h_out = (h_in + upd) * node_mask
    let mut d_pre = vec![0.0; n * h];
    for nd in 0..n {
        let nm = b.nmask[nd];
        if nm == 0.0 {
            continue;
        }
        for j in 0..h {
            d_pre[nd * h + j] = d_h[nd * h + j] * nm;
        }
    }
    let mut d_h_in = d_pre.clone();

    // upd = silu(an1) @ nw2 + nb2
    gw_into(p, &lc.s1, &d_pre, &mut gl.nw2, n, h, h);
    colsum_into(&d_pre, &mut gl.nb2, n, h);
    let mut d_s1 = vec![0.0; n * h];
    gx_into(p, &d_pre, &lp.nw2, &mut d_s1, n, h, h);
    let d_an1 = mul_dsilu_p(p, &d_s1, &lc.an1);

    // an1 = [h_in | hagg * inv_deg] @ nw1 + nb1
    let mut nin = vec![0.0; n * 2 * h];
    for nd in 0..n {
        nin[nd * 2 * h..nd * 2 * h + h].copy_from_slice(&lc.h_in[nd * h..(nd + 1) * h]);
        let id = inv_deg[nd];
        for j in 0..h {
            nin[nd * 2 * h + h + j] = lc.hagg[nd * h + j] * id;
        }
    }
    gw_into(p, &nin, &d_an1, &mut gl.nw1, n, 2 * h, h);
    colsum_into(&d_an1, &mut gl.nb1, n, h);
    let mut d_nin = vec![0.0; n * 2 * h];
    gx_into(p, &d_an1, &lp.nw1, &mut d_nin, n, 2 * h, h);
    let mut d_hagg = vec![0.0; n * h];
    for nd in 0..n {
        let id = inv_deg[nd];
        for j in 0..h {
            d_h_in[nd * h + j] += d_nin[nd * 2 * h + j];
            d_hagg[nd * h + j] = d_nin[nd * 2 * h + h + j] * id;
        }
    }

    // Gather the scatter-sums back to edges: message + gate paths.
    let mut d_m = vec![0.0; e * h];
    let mut d_ag = vec![0.0; e];
    for ei in 0..e {
        let em = b.emask[ei];
        if em == 0.0 {
            continue;
        }
        let nd = b.dst[ei];
        for j in 0..h {
            d_m[ei * h + j] = d_hagg[nd * h + j] * em;
        }
        let sc = inv_deg[nd] * b.nmask[nd] * em;
        let mut dg = 0.0;
        for k in 0..3 {
            dg += d_v[nd * 3 + k] * b.rel_hat[ei * 3 + k];
        }
        let t = lc.gate[ei];
        d_ag[ei] = dg * sc * (1.0 - t * t);
    }
    for ei in 0..e {
        let da = d_ag[ei];
        gl.bg += da;
        if da == 0.0 {
            continue;
        }
        let mrow = &lc.m[ei * h..(ei + 1) * h];
        let drow = &mut d_m[ei * h..(ei + 1) * h];
        for j in 0..h {
            gl.wg[j] += mrow[j] * da;
            drow[j] += da * lp.wg[j];
        }
    }

    // m = silu(ae2) * emask
    let mut d_ae2 = vec![0.0; e * h];
    for ei in 0..e {
        let em = b.emask[ei];
        if em == 0.0 {
            continue;
        }
        for j in 0..h {
            d_ae2[ei * h + j] = d_m[ei * h + j] * em * dsilu_p(p, lc.ae2[ei * h + j]);
        }
    }
    gw_into(p, &lc.u, &d_ae2, &mut gl.ew2, e, h, h);
    colsum_into(&d_ae2, &mut gl.eb2, e, h);
    let mut d_u = vec![0.0; e * h];
    gx_into(p, &d_ae2, &lp.ew2, &mut d_u, e, h, h);
    let d_ae1 = mul_dsilu_p(p, &d_u, &lc.ae1);

    // ae1 = [h_src | h_dst | rbf] @ ew1 + eb1
    let mut x = vec![0.0; e * kx];
    build_edge_input(&mut x, &lc.h_in, rbf, b, dims);
    gw_into(p, &x, &d_ae1, &mut gl.ew1, e, kx, h);
    colsum_into(&d_ae1, &mut gl.eb1, e, h);
    let mut d_x = vec![0.0; e * kx];
    gx_into(p, &d_ae1, &lp.ew1, &mut d_x, e, kx, h);
    for ei in 0..e {
        if b.emask[ei] == 0.0 {
            continue; // padded-edge rows of d_x are exactly zero
        }
        let (si, di) = (b.src[ei], b.dst[ei]);
        for j in 0..h {
            d_h_in[si * h + j] += d_x[ei * kx + j];
            d_h_in[di * h + j] += d_x[ei * kx + h + j];
        }
    }
    d_h_in
}

/// h0 = embed[species] * node_mask.
fn embed_backward(dims: &EgnnDims, b: &Batch64, d_h: &[f64], ge: &mut EncoderParams) {
    let (n, h) = (dims.n, dims.h);
    for nd in 0..n {
        let nm = b.nmask[nd];
        if nm == 0.0 {
            continue;
        }
        let sp = b.species[nd];
        for j in 0..h {
            ge.embed[sp * h + j] += d_h[nd * h + j] * nm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kernels::silu;

    #[test]
    fn silu_derivative_matches_finite_difference() {
        for &a in &[-3.0, -0.5, 0.0, 0.7, 4.2] {
            let eps = 1e-6;
            let fd = (silu(a + eps) - silu(a - eps)) / (2.0 * eps);
            assert!((dsilu(a) - fd).abs() < 1e-8, "a={a}: {} vs {fd}", dsilu(a));
        }
    }

    // The matmul-kernel unit/property tests (threaded bit-identity, naive
    // transpose-product oracles, blocked-f32 vs f64 tolerance, thread-count
    // independence) live with the kernels in `crate::model::kernels`.

    #[test]
    fn mixed_dispatch_tracks_f64_activations() {
        for &a in &[-3.0, -0.5, 0.0, 0.7, 4.2] {
            assert!(
                (dsilu_p(Precision::MixedF32, a) - dsilu(a)).abs() < 1e-5,
                "dsilu({a})"
            );
            assert!(
                (tanh_p(Precision::MixedF32, a) - a.tanh()).abs() < 1e-6,
                "tanh({a})"
            );
        }
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn checkpointed_backward_is_bit_identical() {
        use crate::data::batch::BatchPool;
        use crate::data::graph::radius_graph_positions;
        use crate::model::params::ParamSet;
        use crate::runtime::manifest::{Manifest, ManifestConfig};

        let m = Manifest::synthesize(ManifestConfig::default_native());
        let params = ParamSet::init(&m.params, 5);
        let mut rng = crate::util::rng::Rng::new(7);
        let (species, positions) =
            crate::data::generators::inorganic::build_crystal(&mut rng, &[12, 8, 11], 20);
        let (energy, forces) =
            crate::data::potential::energy_and_forces(&species, &positions);
        let edges = radius_graph_positions(&positions, m.config.cutoff);
        let mut pool = BatchPool::new();
        let mut batch = pool.acquire(m.config.batch_dims());
        batch.push_raw(&species, &forces, energy / species.len() as f64, &edges).unwrap();

        for precision in [Precision::F64, Precision::MixedF32] {
            let dims = EgnnDims::from_config_with(&m.config, precision);
            let enc = EncoderParams::from_set(&dims, &params).unwrap();
            let br = BranchParams::from_set(&dims, &params).unwrap();
            let b = Batch64::new(&dims, &batch).unwrap();

            let es = encoder_forward(&dims, &enc, &b);
            let bs = branch_forward(&dims, &br, &es, &b);
            let (ge, gb) = backward(&dims, &enc, &br, &es, &bs, &b);

            let ck = encoder_forward_checkpoint(&dims, &enc, &b);
            assert_eq!(bits(&es.h), bits(&ck.h), "{precision:?} forward h");
            assert_eq!(bits(&es.v), bits(&ck.v), "{precision:?} forward v");
            let bs2 = branch_forward_h(&dims, &br, &ck.h, &ck.v, &b);
            assert_eq!(bits(&bs.forces), bits(&bs2.forces), "{precision:?} forces");
            assert_eq!(bits(&bs.e_pa), bits(&bs2.e_pa), "{precision:?} e_pa");
            let (ge2, gb2) = backward_checkpoint(&dims, &enc, &br, &ck, &bs2, &b);
            assert_eq!(bits(&ge.embed), bits(&ge2.embed), "{precision:?} d embed");
            for li in 0..dims.l {
                let (a, c) = (&ge.layers[li], &ge2.layers[li]);
                assert_eq!(bits(&a.ew1), bits(&c.ew1), "{precision:?} L{li} d ew1");
                assert_eq!(bits(&a.wg), bits(&c.wg), "{precision:?} L{li} d wg");
                assert_eq!(a.bg.to_bits(), c.bg.to_bits(), "{precision:?} L{li} d bg");
                assert_eq!(bits(&a.nw1), bits(&c.nw1), "{precision:?} L{li} d nw1");
                assert_eq!(bits(&a.nw2), bits(&c.nw2), "{precision:?} L{li} d nw2");
            }
            assert_eq!(bits(&gb.tw1), bits(&gb2.tw1), "{precision:?} d tw1");
            assert_eq!(bits(&gb.ew), bits(&gb2.ew), "{precision:?} d ew");
            assert_eq!(gb.eb.to_bits(), gb2.eb.to_bits(), "{precision:?} d eb");
            assert_eq!(gb.fb.to_bits(), gb2.fb.to_bits(), "{precision:?} d fb");
        }
    }
}
